//! Seeded chaos for the live harness: fault schedules, a deterministic
//! message interposer, and the structured event log.
//!
//! The schedule speaks the same grammar as the simulator's
//! [`netsim::faults`](crate::netsim::faults) traces — worker kills are DC
//! losses, slow-node stalls are `SlowNode` degradations, revivals are
//! `recover_at` — but strikes a *live* run: kills and stalls are executed
//! by the worker threads at iteration boundaries, drops/delays are ruled
//! per message by a [`ChaosInterposer`] armed on the
//! [`Fabric`](crate::comm::fabric::Fabric). Everything derives from one
//! SplitMix64 seed:
//!
//! * node faults come from [`ChaosSchedule::random`] (seeded
//!   [`Rng`](crate::util::rng::Rng));
//! * per-message verdicts hash `(seed, src, dst, seq)` statelessly, so the
//!   ruling for the *k*-th message of a channel pair is a pure function of
//!   the seed — independent of thread interleaving across pairs.
//!
//! The [`EventLog`] records only control-plane facts in deterministic
//! units (epochs, node ids, committed iterations — never wall-clock), so
//! two runs of the same seed render byte-identical logs and any divergence
//! diffs down to the first differing line.

use anyhow::{ensure, Result};

use crate::comm::fabric::{Interposer, Verdict};
use crate::netsim::faults::FailureTrace;
use crate::plan::replanner::elastic::RecoveryMode;
use crate::util::rng::Rng;

/// SplitMix64 — the same mixer `util::rng` seeds with; used here as a
/// stateless hash so verdicts need no shared mutable state.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Hash to `[0, 1)`.
fn unit(x: u64) -> f64 {
    (splitmix64(x) >> 11) as f64 / (1u64 << 53) as f64
}

/// What happens to a node at its scheduled iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NodeFaultKind {
    /// The worker thread exits before executing the iteration (crash).
    Kill,
    /// The worker sleeps this many wall seconds before the iteration
    /// (beats stop during the sleep). Stalls longer than the lease timeout
    /// are evicted; shorter ones must ride out undetected.
    Stall(f64),
}

/// One scheduled node fault, in *global iteration* units.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFault {
    pub node: usize,
    /// Fires when the node is first about to execute this iteration.
    pub at_iter: usize,
    pub kind: NodeFaultKind,
    /// For kills only: re-admit a fresh worker for this node id once the
    /// committed iteration reaches this bound (`recovering_at` grammar).
    pub revive_at: Option<usize>,
}

/// Knobs for [`ChaosSchedule::random`].
#[derive(Clone, Copy, Debug)]
pub struct ChaosCfg {
    pub seed: u64,
    /// Number of node faults (kills/stalls) to schedule.
    pub faults: usize,
    /// Per-message drop probability on interposed channels.
    pub drop_p: f64,
    /// Per-message delay probability; delays are uniform in
    /// `(0, max_delay_sim_secs]` **simulated** seconds.
    pub delay_p: f64,
    pub max_delay_sim_secs: f64,
    /// Whether killed nodes are revived later in the run.
    pub revive: bool,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        Self {
            seed: 0,
            faults: 1,
            drop_p: 0.05,
            delay_p: 0.10,
            max_delay_sim_secs: 0.5,
            revive: false,
        }
    }
}

impl ChaosCfg {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (0.0..=0.2).contains(&self.drop_p),
            "drop probability {} outside [0, 0.2] — higher rates starve the \
             bounded ack-retry data plane",
            self.drop_p
        );
        ensure!(
            (0.0..=1.0).contains(&self.delay_p),
            "delay probability {} outside [0, 1]",
            self.delay_p
        );
        ensure!(
            self.max_delay_sim_secs.is_finite() && self.max_delay_sim_secs >= 0.0,
            "max delay {} must be finite and non-negative",
            self.max_delay_sim_secs
        );
        Ok(())
    }
}

/// A fully resolved chaos schedule for one harness run.
#[derive(Clone, Debug)]
pub struct ChaosSchedule {
    pub seed: u64,
    pub node_faults: Vec<NodeFault>,
    pub drop_p: f64,
    pub delay_p: f64,
    pub max_delay_sim_secs: f64,
}

impl ChaosSchedule {
    /// A fault-free schedule (still seeded: the seed names the run).
    pub fn none(seed: u64) -> Self {
        Self { seed, node_faults: Vec::new(), drop_p: 0.0, delay_p: 0.0, max_delay_sim_secs: 0.0 }
    }

    /// Builder: crash `node` before it executes `at_iter`.
    pub fn kill(mut self, node: usize, at_iter: usize) -> Self {
        self.node_faults.push(NodeFault { node, at_iter, kind: NodeFaultKind::Kill, revive_at: None });
        self.sort();
        self
    }

    /// Builder: stall `node` for `secs` wall seconds before `at_iter`.
    pub fn stall(mut self, node: usize, at_iter: usize, secs: f64) -> Self {
        self.node_faults
            .push(NodeFault { node, at_iter, kind: NodeFaultKind::Stall(secs), revive_at: None });
        self.sort();
        self
    }

    /// Builder: the most recently added fault revives at committed
    /// iteration `revive_at` (kills only; `recovering_at` grammar).
    pub fn reviving_at(mut self, revive_at: usize) -> Self {
        if let Some(f) = self.node_faults.last_mut() {
            f.revive_at = Some(revive_at);
        }
        self
    }

    /// Builder: per-message drop/delay chaos on the interposed channels.
    pub fn with_message_chaos(mut self, drop_p: f64, delay_p: f64, max_delay_sim_secs: f64) -> Self {
        self.drop_p = drop_p;
        self.delay_p = delay_p;
        self.max_delay_sim_secs = max_delay_sim_secs;
        self
    }

    fn sort(&mut self) {
        self.node_faults.sort_by_key(|f| (f.at_iter, f.node));
    }

    /// Seeded random schedule over `nodes` workers and `iters` iterations.
    ///
    /// Guarantees that make soak runs meaningful and deterministic:
    /// * at least two nodes survive all kills (the re-solved layout keeps a
    ///   cross-DC structure);
    /// * at most one fault per node (no kill-the-corpse schedules);
    /// * kills land in `[1, iters)` so at least one iteration commits first;
    /// * stalls are either *short* (`0.3 ×` the lease timeout — must ride
    ///   out undetected) or *long* (`3 ×` — must be evicted), never near
    ///   the detection boundary where wall-clock jitter could flip the log.
    pub fn random(nodes: usize, iters: usize, lease_timeout_secs: f64, cfg: &ChaosCfg) -> Result<Self> {
        cfg.validate()?;
        ensure!(nodes >= 3, "chaos schedules need >= 3 nodes, got {nodes}");
        ensure!(iters >= 4, "chaos schedules need >= 4 iterations, got {iters}");
        let mut rng = Rng::new(cfg.seed);
        let mut out = Self::none(cfg.seed).with_message_chaos(
            cfg.drop_p,
            cfg.delay_p,
            cfg.max_delay_sim_secs,
        );
        let max_kills = nodes - 2; // keep two survivors
        let mut kills = 0usize;
        let mut victims: Vec<usize> = (0..nodes).collect();
        rng.shuffle(&mut victims);
        for &node in victims.iter().take(cfg.faults) {
            let at_iter = 1 + rng.below(iters - 1);
            let kill = kills < max_kills && rng.below(2) == 0;
            if kill {
                kills += 1;
                let revive_at = (cfg.revive && at_iter + 2 < iters)
                    .then(|| at_iter + 1 + rng.below(iters - at_iter - 1));
                out.node_faults.push(NodeFault {
                    node,
                    at_iter,
                    kind: NodeFaultKind::Kill,
                    revive_at,
                });
            } else {
                let secs = if rng.below(2) == 0 {
                    0.3 * lease_timeout_secs
                } else {
                    3.0 * lease_timeout_secs
                };
                out.node_faults.push(NodeFault {
                    node,
                    at_iter,
                    kind: NodeFaultKind::Stall(secs),
                    revive_at: None,
                });
            }
        }
        out.sort();
        Ok(out)
    }

    /// Nudge every fault off checkpoint-boundary iterations (multiples of
    /// `interval`), keeping `at_iter` in `[1, iters)`. A fault *at* a
    /// boundary races the manifest publication for that boundary — whether
    /// the last shard lands before the death is wall-clock luck, which
    /// would make the event log timing-dependent. One iteration of drift
    /// preserves the schedule's shape while keeping logs byte-stable.
    /// Identity when `interval <= 1` (every iteration is a boundary) or the
    /// fault already sits off-boundary.
    pub fn aligned_to(mut self, interval: usize, iters: usize) -> Self {
        if interval > 1 {
            for f in &mut self.node_faults {
                if f.at_iter % interval == 0 {
                    // prefer drifting later; step back from the end of run
                    f.at_iter = if f.at_iter + 1 < iters {
                        f.at_iter + 1
                    } else {
                        f.at_iter.saturating_sub(1).max(1)
                    };
                }
                if let Some(r) = f.revive_at {
                    f.revive_at = (r > f.at_iter + 1).then_some(r).or(Some(f.at_iter + 2));
                }
            }
            self.sort();
        }
        self
    }

    /// The simulator-side expression of this schedule: kills are DC losses,
    /// stalls are `SlowNode` degradations, revivals are `recover_at` — the
    /// bridge that lets `netsim` replay what the live harness executed.
    pub fn as_failure_trace(&self, iter_secs: f64) -> FailureTrace {
        let mut t = FailureTrace::empty();
        for f in &self.node_faults {
            let at = f.at_iter as f64 * iter_secs;
            match f.kind {
                NodeFaultKind::Kill => {
                    t = t.dc_loss(at, f.node);
                    if let Some(r) = f.revive_at {
                        t = t.recovering_at(r as f64 * iter_secs);
                    }
                }
                NodeFaultKind::Stall(secs) => {
                    t = t.slow_node(at, 0, f.node, 0.1).recovering_at(at + secs);
                }
            }
        }
        t
    }

    /// Faults this node executes itself, sorted by iteration. `after`
    /// filters to strictly later iterations (revived workers must not
    /// re-fire the kill that created them).
    pub fn faults_for(&self, node: usize, after: Option<usize>) -> Vec<NodeFault> {
        self.node_faults
            .iter()
            .filter(|f| f.node == node && after.map_or(true, |a| f.at_iter > a))
            .copied()
            .collect()
    }

    /// The interposer expressing this schedule's message chaos.
    pub fn interposer(&self) -> ChaosInterposer {
        ChaosInterposer {
            seed: self.seed,
            drop_p: self.drop_p,
            delay_p: self.delay_p,
            max_delay_sim_secs: self.max_delay_sim_secs,
        }
    }
}

/// Stateless seeded interposer: the verdict for message `seq` of pair
/// `(src, dst)` is a pure function of `(seed, src, dst, seq)`.
#[derive(Clone, Copy, Debug)]
pub struct ChaosInterposer {
    pub seed: u64,
    pub drop_p: f64,
    pub delay_p: f64,
    pub max_delay_sim_secs: f64,
}

impl Interposer for ChaosInterposer {
    fn verdict(&self, src: usize, dst: usize, _bytes: usize, seq: u64) -> Verdict {
        let key = self
            .seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add((src as u64) << 42)
            .wrapping_add((dst as u64) << 21)
            .wrapping_add(seq);
        let u = unit(key);
        if u < self.drop_p {
            Verdict::Drop
        } else if u < self.drop_p + self.delay_p {
            // an independent sub-draw sizes the delay
            Verdict::Delay(unit(key ^ 0x5ca1_ab1e) * self.max_delay_sim_secs)
        } else {
            Verdict::Deliver
        }
    }
}

/// One control-plane fact. Every field is deterministic under a fixed
/// schedule — node ids, epochs, committed iterations — never wall-clock.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A new epoch began with this membership, executing from `start_iter`.
    EpochStart { epoch: u64, members: Vec<usize>, start_iter: usize },
    /// All members durably checkpointed `iter` (manifest published).
    CheckpointSaved { epoch: u64, iter: usize },
    /// A member's lease expired. `done` is the *node's* completed-iteration
    /// count at detection — a deterministic quantity under a fixed schedule
    /// (the node died/stalled at a scheduled iteration), unlike the run's
    /// global committed count, which can wobble by one with message-chaos
    /// timing.
    LeaseExpired { epoch: u64, node: usize, done: usize },
    /// Recovery ran: `dead` evicted (or `joined` admitted), rolling back to
    /// `start_iter` under `mode`.
    Recovery {
        epoch: u64,
        mode: RecoveryMode,
        dead: Vec<usize>,
        joined: Vec<usize>,
        start_iter: usize,
        restored_from: Option<usize>,
    },
    /// The run committed all requested iterations.
    Finished { epoch: u64, committed: usize },
}

/// Append-only, deterministically renderable run journal.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl EventLog {
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn count(&self, pred: impl Fn(&Event) -> bool) -> usize {
        self.events.iter().filter(|e| pred(e)).count()
    }

    /// Canonical one-line-per-event rendering; byte-identical across runs
    /// of the same seed (the soak gate diffs this).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            match e {
                Event::EpochStart { epoch, members, start_iter } => {
                    out.push_str(&format!(
                        "epoch {epoch} start members={members:?} from_iter={start_iter}\n"
                    ));
                }
                Event::CheckpointSaved { epoch, iter } => {
                    out.push_str(&format!("epoch {epoch} checkpoint iter={iter}\n"));
                }
                Event::LeaseExpired { epoch, node, done } => {
                    out.push_str(&format!(
                        "epoch {epoch} lease-expired node={node} done={done}\n"
                    ));
                }
                Event::Recovery { epoch, mode, dead, joined, start_iter, restored_from } => {
                    out.push_str(&format!(
                        "epoch {epoch} recovery mode={mode:?} dead={dead:?} joined={joined:?} \
                         resume_from={start_iter} restored_from={restored_from:?}\n"
                    ));
                }
                Event::Finished { epoch, committed } => {
                    out.push_str(&format!("epoch {epoch} finished committed={committed}\n"));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite: interposer drop/delay rulings are deterministic under a
    /// fixed seed — per (src, dst, seq), independent of call order — and
    /// empirical rates track the configured probabilities.
    #[test]
    fn interposer_is_deterministic_and_rate_faithful() {
        let sched = ChaosSchedule::none(42).with_message_chaos(0.1, 0.2, 0.5);
        let a = sched.interposer();
        let b = sched.interposer();
        let mut drops = 0usize;
        let mut delays = 0usize;
        let n = 20_000u64;
        for seq in 0..n {
            let (src, dst) = ((seq % 5) as usize, ((seq / 5) % 5) as usize);
            let va = a.verdict(src, dst, 64, seq);
            assert_eq!(va, b.verdict(src, dst, 64, seq), "divergence at seq {seq}");
            match va {
                Verdict::Drop => drops += 1,
                Verdict::Delay(d) => {
                    assert!((0.0..=0.5).contains(&d), "delay {d} out of range");
                    delays += 1;
                }
                Verdict::Deliver => {}
            }
        }
        let (dr, de) = (drops as f64 / n as f64, delays as f64 / n as f64);
        assert!((dr - 0.1).abs() < 0.02, "drop rate {dr} far from 0.1");
        assert!((de - 0.2).abs() < 0.02, "delay rate {de} far from 0.2");
        // a different seed rules differently somewhere
        let c = ChaosSchedule::none(43).with_message_chaos(0.1, 0.2, 0.5).interposer();
        assert!(
            (0..1000).any(|s| c.verdict(0, 1, 64, s) != a.verdict(0, 1, 64, s)),
            "seed does not influence verdicts"
        );
    }

    #[test]
    fn random_schedules_are_reproducible_and_respect_invariants() {
        let cfg = ChaosCfg { seed: 7, faults: 3, revive: true, ..ChaosCfg::default() };
        let a = ChaosSchedule::random(5, 24, 0.4, &cfg).unwrap();
        let b = ChaosSchedule::random(5, 24, 0.4, &cfg).unwrap();
        assert_eq!(a.node_faults, b.node_faults, "same seed, same schedule");
        for seed in 0..32u64 {
            let s = ChaosSchedule::random(5, 24, 0.4, &ChaosCfg { seed, ..cfg }).unwrap();
            assert_eq!(s.node_faults.len(), 3);
            let kills: Vec<_> = s
                .node_faults
                .iter()
                .filter(|f| matches!(f.kind, NodeFaultKind::Kill))
                .collect();
            assert!(kills.len() <= 3, "two survivors required");
            let mut nodes: Vec<_> = s.node_faults.iter().map(|f| f.node).collect();
            nodes.sort_unstable();
            nodes.dedup();
            assert_eq!(nodes.len(), 3, "at most one fault per node");
            for f in &s.node_faults {
                assert!(f.at_iter >= 1 && f.at_iter < 24);
                if let Some(r) = f.revive_at {
                    assert!(r > f.at_iter && r < 24, "revive window: {f:?}");
                }
                if let NodeFaultKind::Stall(secs) = f.kind {
                    let ratio = secs / 0.4;
                    assert!(
                        (ratio - 0.3).abs() < 1e-9 || (ratio - 3.0).abs() < 1e-9,
                        "stall {secs}s sits near the detection boundary"
                    );
                }
            }
        }
        // degenerate inputs error descriptively
        assert!(ChaosSchedule::random(2, 24, 0.4, &cfg).is_err());
        assert!(ChaosSchedule::random(5, 2, 0.4, &cfg).is_err());
        let bad = ChaosCfg { drop_p: 0.9, ..ChaosCfg::default() };
        assert!(ChaosSchedule::random(5, 24, 0.4, &bad).is_err());
    }

    #[test]
    fn schedule_bridges_to_the_netsim_trace_grammar() {
        let s = ChaosSchedule::none(1).kill(2, 5).reviving_at(9).stall(0, 3, 1.2);
        let t = s.as_failure_trace(2.0);
        assert_eq!(t.events.len(), 2);
        // builder sort puts the stall (iter 3) first
        assert_eq!(t.events[0].at, 6.0);
        assert!(!t.events[0].is_permanent(), "stalls recover");
        assert_eq!(t.events[1].at, 10.0);
        assert_eq!(t.events[1].recover_at, Some(18.0));
    }

    #[test]
    fn aligned_to_keeps_faults_off_checkpoint_boundaries() {
        let s = ChaosSchedule::none(3).kill(1, 8).reviving_at(9).stall(2, 5, 0.1).kill(0, 23);
        let a = s.clone().aligned_to(4, 24);
        for f in &a.node_faults {
            assert!(f.at_iter % 4 != 0, "fault still on a boundary: {f:?}");
            assert!(f.at_iter >= 1 && f.at_iter < 24);
            if let Some(r) = f.revive_at {
                assert!(r > f.at_iter, "revive precedes the kill: {f:?}");
            }
        }
        // off-boundary faults are untouched; interval 1 is the identity
        assert!(a.node_faults.iter().any(|f| f.node == 2 && f.at_iter == 5));
        assert_eq!(s.clone().aligned_to(1, 24).node_faults, s.node_faults);
        // end-of-run boundary faults step back, not past the horizon
        let edge = ChaosSchedule::none(0).kill(0, 24).aligned_to(4, 24);
        assert_eq!(edge.node_faults[0].at_iter, 23);
    }

    #[test]
    fn faults_for_filters_by_node_and_revival_horizon() {
        let s = ChaosSchedule::none(1).kill(2, 5).kill(1, 3).stall(2, 9, 0.1);
        assert_eq!(s.faults_for(2, None).len(), 2);
        assert_eq!(s.faults_for(1, None).len(), 1);
        assert_eq!(s.faults_for(0, None).len(), 0);
        // a worker revived after iter 5 must not re-fire the iter-5 kill
        let later = s.faults_for(2, Some(5));
        assert_eq!(later.len(), 1);
        assert_eq!(later[0].at_iter, 9);
    }

    #[test]
    fn event_log_renders_deterministically() {
        let mut log = EventLog::default();
        log.push(Event::EpochStart { epoch: 0, members: vec![0, 1, 2], start_iter: 0 });
        log.push(Event::LeaseExpired { epoch: 0, node: 1, done: 4 });
        log.push(Event::Recovery {
            epoch: 1,
            mode: RecoveryMode::Elastic,
            dead: vec![1],
            joined: vec![],
            start_iter: 4,
            restored_from: Some(4),
        });
        log.push(Event::Finished { epoch: 1, committed: 8 });
        let text = log.to_text();
        assert_eq!(text.lines().count(), 4);
        assert!(text.contains("lease-expired node=1 done=4"));
        assert!(text.contains("mode=Elastic dead=[1]"));
        assert_eq!(log.count(|e| matches!(e, Event::LeaseExpired { .. })), 1);
    }
}
