//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (input order/shapes/dtypes, parameter layout, expert slots).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// One flat parameter slot of a profile.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// true for MoE expert weights (the SR-migration targets)
    pub expert: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A lowered model profile (train_step + eval + init params).
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub h: usize,
    pub m: usize,
    pub e: usize,
    pub k: usize,
    pub n_layers: usize,
    pub capacity: usize,
    pub param_count: usize,
    pub n_leaves: usize,
    pub param_spec: Vec<ParamSpec>,
    pub expert_slots: Vec<usize>,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    pub params_file: PathBuf,
}

/// The artifacts directory + parsed manifest.
pub struct Artifacts {
    pub root: PathBuf,
    pub manifest: Value,
}

impl Artifacts {
    /// Locate artifacts: `$HYBRID_EP_ARTIFACTS`, `./artifacts`, or the crate
    /// root's `artifacts/` (works from tests, benches and examples).
    pub fn discover() -> Result<Self> {
        let mut candidates: Vec<PathBuf> = Vec::new();
        if let Ok(p) = std::env::var("HYBRID_EP_ARTIFACTS") {
            candidates.push(PathBuf::from(p));
        }
        candidates.push(PathBuf::from("artifacts"));
        candidates.push(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return Self::load(c);
            }
        }
        bail!(
            "artifacts not found (searched {candidates:?}); run `make artifacts` first"
        )
    }

    pub fn available() -> bool {
        Self::discover().is_ok()
    }

    pub fn load(root: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", root.display()))?;
        let manifest = Value::parse(&text).context("parsing manifest.json")?;
        Ok(Self { root: root.to_path_buf(), manifest })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.root.join(file)
    }

    pub fn profile(&self, name: &str) -> Result<Profile> {
        let p = self
            .manifest
            .at(&["profiles", name])
            .with_context(|| format!("profile {name:?} not in manifest"))?;
        let cfg = p.req("config")?;
        let spec = p
            .req("param_spec")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(ParamSpec {
                    name: s.req("name")?.as_str()?.to_string(),
                    shape: s.req("shape")?.as_usize_vec()?,
                    dtype: s.req("dtype")?.as_str()?.to_string(),
                    expert: s.req("expert_weight")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Profile {
            name: name.to_string(),
            vocab: cfg.req("vocab")?.as_usize()?,
            seq: cfg.req("seq")?.as_usize()?,
            batch: cfg.req("batch")?.as_usize()?,
            h: cfg.req("h")?.as_usize()?,
            m: cfg.req("m")?.as_usize()?,
            e: cfg.req("e")?.as_usize()?,
            k: cfg.req("k")?.as_usize()?,
            n_layers: cfg.req("n_layers")?.as_usize()?,
            capacity: p.req("capacity")?.as_usize()?,
            param_count: p.req("param_count")?.as_usize()?,
            n_leaves: p.req("n_leaves")?.as_usize()?,
            expert_slots: p.req("expert_slots")?.as_usize_vec()?,
            train_file: self.path(p.at(&["train_step", "file"])?.as_str()?),
            eval_file: self.path(p.at(&["eval", "file"])?.as_str()?),
            params_file: self.path(p.req("params_file")?.as_str()?),
            param_spec: spec,
        })
    }

    /// Initial parameters as per-slot f32 buffers (flatten_spec order).
    pub fn load_params(&self, profile: &Profile) -> Result<Vec<Vec<f32>>> {
        let raw = std::fs::read(&profile.params_file)
            .with_context(|| format!("reading {}", profile.params_file.display()))?;
        if raw.len() != 4 * profile.param_count {
            bail!(
                "params file {} has {} bytes, expected {}",
                profile.params_file.display(),
                raw.len(),
                4 * profile.param_count
            );
        }
        let mut out = Vec::with_capacity(profile.param_spec.len());
        let mut off = 0usize;
        for spec in &profile.param_spec {
            let n = spec.numel();
            let mut buf = Vec::with_capacity(n);
            for i in 0..n {
                let o = (off + i) * 4;
                buf.push(f32::from_le_bytes(raw[o..o + 4].try_into().unwrap()));
            }
            off += n;
            out.push(buf);
        }
        if off != profile.param_count {
            bail!("param spec covers {off} of {} elements", profile.param_count);
        }
        Ok(out)
    }

    /// GeMM artifact (Fig. 11): returns (path, l, h, m).
    pub fn gemm(&self, l: usize, h: usize, m: usize) -> Result<PathBuf> {
        let key = format!("{l}x{h}x{m}");
        let e = self.manifest.at(&["gemm", &key])?;
        Ok(self.path(e.req("file")?.as_str()?))
    }

    pub fn gemm_sizes(&self) -> Result<Vec<(usize, usize, usize)>> {
        let mut out = Vec::new();
        for key in self.manifest.req("gemm")?.as_obj()?.keys() {
            let parts: Vec<usize> =
                key.split('x').map(|x| x.parse().unwrap_or(0)).collect();
            if parts.len() == 3 {
                out.push((parts[0], parts[1], parts[2]));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Demo-stage artifact path + its config field.
    pub fn demo_entry(&self, name: &str) -> Result<PathBuf> {
        let e = self.manifest.at(&["demo", "entries", name])?;
        Ok(self.path(e.req("file")?.as_str()?))
    }

    pub fn demo_config(&self) -> Result<&Value> {
        self.manifest.at(&["demo", "config"])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> Option<Artifacts> {
        match Artifacts::discover() {
            Ok(a) => Some(a),
            Err(_) => {
                eprintln!("skipping: artifacts not built");
                None
            }
        }
    }

    #[test]
    fn profile_parses_and_params_load() {
        let Some(a) = arts() else { return };
        let p = a.profile("test").unwrap();
        assert_eq!(p.vocab, 64);
        assert_eq!(p.param_spec.len(), p.n_leaves);
        assert!(!p.expert_slots.is_empty());
        let params = a.load_params(&p).unwrap();
        assert_eq!(params.len(), p.n_leaves);
        let total: usize = params.iter().map(|b| b.len()).sum();
        assert_eq!(total, p.param_count);
        // expert slots lead with the expert dimension
        for &s in &p.expert_slots {
            assert_eq!(p.param_spec[s].shape[0], p.e);
            assert!(p.param_spec[s].expert);
        }
    }

    #[test]
    fn unknown_profile_errors() {
        let Some(a) = arts() else { return };
        assert!(a.profile("nonexistent").is_err());
    }

    #[test]
    fn gemm_listing() {
        let Some(a) = arts() else { return };
        let sizes = a.gemm_sizes().unwrap();
        assert!(sizes.contains(&(512, 512, 512)));
        assert!(a.gemm(512, 512, 512).unwrap().exists());
    }
}
