//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! request-path half: [`artifacts`] parses `manifest.json`, [`exec`] loads
//! HLO **text** (`HloModuleProto::from_text_file` — the text parser reassigns
//! instruction ids, which is why text, not serialized protos, is the
//! interchange format with jax ≥ 0.5), compiles on `PjRtClient::cpu()` and
//! executes with concrete inputs.

pub mod artifacts;
pub mod exec;

pub use artifacts::{Artifacts, ParamSpec, Profile};
pub use exec::{Engine, Executable};
