//! Runtime: PJRT execution of AOT-compiled JAX/Pallas artifacts, plus the
//! live multi-node chaos harness.
//!
//! Python runs once at build time (`make artifacts`); [`artifacts`] parses
//! `manifest.json`, [`exec`] loads HLO **text**
//! (`HloModuleProto::from_text_file` — the text parser reassigns
//! instruction ids, which is why text, not serialized protos, is the
//! interchange format with jax ≥ 0.5), compiles on `PjRtClient::cpu()` and
//! executes with concrete inputs.
//!
//! [`harness`] runs a real concurrent trainer (one OS thread per node)
//! under [`chaos`]-scheduled faults: coordinator leases, durable
//! checkpoint manifests, and elastic/failover recovery — the live
//! counterpart of the `netsim` failure simulations.

pub mod artifacts;
pub mod chaos;
pub mod exec;
pub mod harness;

pub use artifacts::{Artifacts, ParamSpec, Profile};
pub use chaos::{ChaosCfg, ChaosSchedule, Event, EventLog};
pub use exec::{Engine, Executable};
pub use harness::{reference_losses, HarnessCfg, HarnessReport};
