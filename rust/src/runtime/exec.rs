//! PJRT execution: compile HLO text once, execute many times.
//!
//! `xla` crate wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so each execution yields one tuple literal that we
//! decompose into the output list.
//!
//! PJRT handles are not `Send` (raw C pointers); each worker thread owns its
//! own [`Engine`].

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client + executable cache for one thread.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&mut self, path: &Path) -> Result<Executable> {
        let key = path.display().to_string();
        if let Some(exe) = self.cache.get(&key) {
            return Ok(Executable { exe: exe.clone() });
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.insert(key, exe.clone());
        Ok(Executable { exe })
    }
}

/// A compiled computation ready to run.
pub struct Executable {
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).context("PJRT execute")?;
        let tuple = result[0][0].to_literal_sync().context("fetching result")?;
        Ok(tuple.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape from a flat buffer.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Zero-filled f32 literal.
pub fn zeros_f32(shape: &[usize]) -> Result<xla::Literal> {
    literal_f32(&vec![0.0; shape.iter().product()], shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    #[test]
    fn gemm_artifact_multiplies() {
        let Ok(a) = Artifacts::discover() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eng = Engine::cpu().unwrap();
        let exe = eng.load(&a.gemm(128, 128, 128).unwrap()).unwrap();
        let n = 128usize;
        let x = literal_f32(&vec![1.0; n * n], &[n, n]).unwrap();
        let y = literal_f32(&vec![2.0; n * n], &[n, n]).unwrap();
        let out = exe.run(&[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(v.len(), n * n);
        assert!(v.iter().all(|&x| (x - 2.0 * n as f32).abs() < 1e-3));
    }

    #[test]
    fn executable_cache_hits() {
        let Ok(a) = Artifacts::discover() else { return };
        let mut eng = Engine::cpu().unwrap();
        let p = a.gemm(128, 128, 128).unwrap();
        let _e1 = eng.load(&p).unwrap();
        let t0 = std::time::Instant::now();
        let _e2 = eng.load(&p).unwrap();
        assert!(t0.elapsed().as_millis() < 50, "second load should be cached");
    }

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
    }
}
