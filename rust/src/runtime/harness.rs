//! Live multi-node chaos harness: real worker threads, leases, durable
//! checkpoints, and elastic recovery — executed, not simulated.
//!
//! Each "node" is an OS thread hosting a shard of a deterministic expert
//! trainer, exchanging real bytes per iteration over the throttled
//! [`Fabric`] (chaos-interposed). A coordinator thread runs the control
//! plane: epoch-numbered membership, heartbeat *leases* parameterized by
//! [`DetectorCfg`] (same knobs as the simulator's detector), interval
//! checkpoints published as manifests through [`CheckpointStore`], and —
//! on a confirmed lease expiry — live recovery mirroring the simulation's
//! [`RecoveryMode`]s: pause, shrink membership, restore the last verified
//! checkpoint, re-solve the layout ([`shrink_cluster`] + the joint
//! solver), resume. `ReplicaFailover` skips the rollback when every lost
//! primary has a surviving replica holder.
//!
//! # Determinism contract
//!
//! The [`EventLog`] must render byte-identically across runs of one seed.
//! Everything logged is therefore derived from *scheduled* quantities:
//! node faults fire at fixed global iterations (nudged off checkpoint
//! boundaries by [`ChaosSchedule::aligned_to`]), `LeaseExpired` records
//! the dead node's own progress (not the global commit, which can wobble
//! by one with ack timing), rollback targets are computed from the dead
//! node's progress (`floor((done - 1)/interval) * interval`) rather than
//! from the wall-clock-dependent commit front, and revivals join at exact
//! commit counts. Message drops/delays/retries are deliberately *not*
//! logged — their timing is real.
//!
//! # Exactly-once iteration accounting
//!
//! Workers track a per-expert `applied` count. Re-executed iterations
//! (after a no-rollback failover or a grow) re-run the *exchange* but
//! skip the already-applied update and re-report the memoized loss, so
//! no optimizer step is ever double-counted; the committed loss history
//! of any chaotic run matches a fault-free run of the same seed.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::{presets, ClusterSpec, ParallelismConfig};
use crate::comm::async_comm::RetryCfg;
use crate::comm::cluster::Message;
use crate::comm::collectives::{bytes_to_f32s, f32s_to_bytes};
use crate::comm::fabric::Fabric;
use crate::migration::checkpoint::{Checkpoint, CheckpointStore};
use crate::model::solver::solve_joint;
use crate::moe::{GpuSpec, MoEWorkload};
use crate::netsim::detect::DetectorCfg;
use crate::plan::replanner::elastic::{shrink_cluster, RecoveryMode};
use crate::runtime::chaos::{ChaosSchedule, Event, EventLog, NodeFault, NodeFaultKind};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Knobs of one harness run.
#[derive(Clone, Debug)]
pub struct HarnessCfg {
    /// Worker threads (one per simulated DC; node ids are stable for the
    /// whole run, eviction never renumbers).
    pub nodes: usize,
    /// Global iterations to commit.
    pub iters: usize,
    pub experts_per_node: usize,
    pub expert_dim: usize,
    /// Dispatch bytes each node sends to each peer per iteration.
    pub payload_bytes: usize,
    pub inter_gbps: f64,
    pub intra_gbps: f64,
    /// Fabric time compression (bandwidth ratios preserved).
    pub time_scale: f64,
    /// Heartbeat lease: period, timeout (in beats), beat size — the same
    /// parameterization the simulator's failure detector uses.
    pub lease: DetectorCfg,
    /// Checkpoint every this many committed iterations.
    pub checkpoint_interval: usize,
    pub store_dir: PathBuf,
    pub recovery: RecoveryMode,
    /// Holders per expert (1 = no replication).
    pub replicas: usize,
    pub seed: u64,
    /// Coordinator watchdog: the run aborts with an error (never wedges)
    /// if it has not finished within this wall bound. Workers hard-stop
    /// at twice this bound even if the control channel is lost.
    pub watchdog_secs: f64,
    /// Ack-retry policy for the reliable data plane (reuses the async
    /// communicator's backoff).
    pub retry: RetryCfg,
}

impl HarnessCfg {
    /// A small, fast configuration for tests and the `--quick` bench.
    pub fn quick(nodes: usize, iters: usize, seed: u64, store_dir: impl Into<PathBuf>) -> Self {
        Self {
            nodes,
            iters,
            experts_per_node: 2,
            expert_dim: 16,
            payload_bytes: 16 * 1024,
            inter_gbps: 20.0,
            intra_gbps: 100.0,
            time_scale: 200.0,
            lease: DetectorCfg { period_secs: 0.025, timeout_beats: 3, beat_bytes: 1e3 },
            checkpoint_interval: 4,
            store_dir: store_dir.into(),
            recovery: RecoveryMode::Elastic,
            replicas: 2,
            seed,
            watchdog_secs: 30.0,
            retry: RetryCfg {
                max_attempts: 12,
                base_backoff: Duration::from_millis(2),
                max_backoff: Duration::from_millis(20),
            },
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.nodes >= 1, "harness needs at least one node");
        ensure!(self.iters >= 1, "harness needs at least one iteration");
        ensure!(self.experts_per_node >= 1, "need at least one expert per node");
        ensure!(self.expert_dim >= 1, "expert dimension must be positive");
        ensure!(self.payload_bytes >= 1, "per-peer payload must be positive");
        ensure!(self.checkpoint_interval >= 1, "checkpoint interval must be >= 1");
        ensure!(
            (1..=self.nodes).contains(&self.replicas),
            "replicas {} outside [1, {}]",
            self.replicas,
            self.nodes
        );
        ensure!(
            self.time_scale.is_finite() && self.time_scale > 0.0,
            "time_scale {} must be finite and positive",
            self.time_scale
        );
        ensure!(
            self.watchdog_secs.is_finite() && self.watchdog_secs > 0.0,
            "watchdog {} must be finite and positive",
            self.watchdog_secs
        );
        ensure!(self.retry.max_attempts >= 1, "retry needs at least one attempt");
        self.lease.validate()?;
        Ok(())
    }

    fn e_total(&self) -> usize {
        self.nodes * self.experts_per_node
    }
}

// ---------------------------------------------------------------------------
// Wire protocol: tags, control plane, data routing
// ---------------------------------------------------------------------------

const PH_DATA: u32 = 1;
const PH_ACK: u32 = 2;
const PH_XFER: u32 = 3;
const PH_XACK: u32 = 4;
/// Per-message framing overhead charged on the fabric.
const FRAME_BYTES: usize = 64;

/// Pack (phase, epoch, index) into a message tag. Epochs wrap at 4096 and
/// indices at 65536 — far beyond any run this harness drives, and stale
/// traffic is additionally fenced by the epoch check on receive.
fn tag(phase: u32, epoch: u64, idx: usize) -> u32 {
    (phase << 28) | ((epoch as u32 & 0xfff) << 16) | (idx as u32 & 0xffff)
}

fn untag(t: u32) -> (u32, u32, usize) {
    (t >> 28, (t >> 16) & 0xfff, (t & 0xffff) as usize)
}

fn epoch_low(epoch: u64) -> u32 {
    (epoch & 0xfff) as u32
}

/// How a worker (re)builds expert state when adopting an epoch plan.
#[derive(Clone, Debug)]
enum Restore {
    /// Keep live state (failover / grow — no rollback).
    Keep,
    /// Deterministic fresh init from the run seed (epoch 0, static restart).
    Scratch,
    /// Load hosted experts from this verified manifest's shard files.
    Manifest(Manifest),
}

/// Everything a worker needs to execute one epoch.
#[derive(Clone, Debug)]
struct EpochPlan {
    epoch: u64,
    members: Vec<usize>,
    start_iter: usize,
    /// Expert -> primary (reports the loss, saves the shard).
    assignment: Vec<(u32, usize)>,
    /// Expert -> all holders in copy order (every holder applies updates).
    hosting: Vec<(u32, Vec<usize>)>,
    restore: Restore,
    /// Live weight migrations `(expert, from, to)` executed over the data
    /// plane before the epoch starts (AG-style expert transmission).
    transfers: Vec<(u32, usize, usize)>,
}

enum Ctrl {
    Epoch(EpochPlan),
    Shutdown,
}

enum ToCoord {
    Beat { node: usize },
    IterDone { node: usize, epoch: u64, iter: usize, loss: f64, experts: usize },
    CkptDone { node: usize, epoch: u64, iter: usize },
    /// Liveness backstop: a worker waited a full lease timeout inside one
    /// exchange. Counted, not acted on — the lease machinery owns recovery.
    Stalled { node: usize },
}

/// Mutable data-plane routing: revived nodes swap a fresh receiver into
/// their slot; sends to dead receivers are silently dropped (the wire ate
/// them — exactly what the ack-retry layer is for).
struct Router {
    slots: Mutex<Vec<Option<Sender<Message>>>>,
}

impl Router {
    fn new(n: usize) -> Self {
        Self { slots: Mutex::new((0..n).map(|_| None).collect()) }
    }

    fn install(&self, node: usize, tx: Sender<Message>) {
        self.slots.lock().unwrap()[node] = Some(tx);
    }

    fn deliver(&self, to: usize, m: Message) {
        if let Some(tx) = self.slots.lock().unwrap()[to].as_ref() {
            let _ = tx.send(m);
        }
    }
}

// ---------------------------------------------------------------------------
// Durable checkpoint manifests
// ---------------------------------------------------------------------------

fn shard_name(iter: usize, epoch: u64, node: usize) -> String {
    format!("shard_i{iter:06}_e{epoch:04}_n{node:03}")
}

fn manifest_name(iter: usize, epoch: u64) -> String {
    format!("manifest_i{iter:06}_e{epoch:04}")
}

/// A published checkpoint generation: every member's primary-expert shard
/// at one boundary. The manifest is written *after* all shards (two-phase
/// publish), so a manifest that exists names only fully-written shards —
/// unless the disk tore them later, which verification catches.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub iter: usize,
    pub epoch: u64,
    pub shards: Vec<(usize, String)>,
}

impl Manifest {
    fn to_bytes(&self) -> Vec<u8> {
        let mut s = format!("{}\n{}\n", self.iter, self.epoch);
        for (node, file) in &self.shards {
            s.push_str(&format!("{node} {file}\n"));
        }
        s.into_bytes()
    }

    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("manifest is not UTF-8")?;
        let mut lines = text.lines();
        let iter: usize =
            lines.next().context("manifest missing iter line")?.trim().parse()?;
        let epoch: u64 =
            lines.next().context("manifest missing epoch line")?.trim().parse()?;
        let mut shards = Vec::new();
        for l in lines {
            let (node, file) = l.split_once(' ').context("malformed shard line")?;
            shards.push((node.parse::<usize>()?, file.to_string()));
        }
        ensure!(!shards.is_empty(), "manifest names no shards");
        Ok(Self { iter, epoch, shards })
    }
}

fn save_shard(
    store: &CheckpointStore,
    iter: usize,
    epoch: u64,
    node: usize,
    expert_ids: &[u32],
    experts: &[Vec<f32>],
    dim: usize,
) -> Result<String> {
    let shared = vec![0.0f32; dim];
    // k = dim keeps every residual coordinate: bit-exact restore
    let ck = Checkpoint::capture(experts, &shared, dim);
    let mut payload = Vec::new();
    payload.extend_from_slice(&(expert_ids.len() as u32).to_le_bytes());
    for e in expert_ids {
        payload.extend_from_slice(&e.to_le_bytes());
    }
    payload.extend_from_slice(&ck.to_bytes());
    let name = shard_name(iter, epoch, node);
    store.save(&name, &payload)?;
    Ok(name)
}

fn load_shard(store: &CheckpointStore, name: &str) -> Result<(Vec<u32>, Checkpoint)> {
    let payload = store.load(name)?;
    ensure!(payload.len() >= 4, "shard {name} too short");
    let n = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    ensure!(payload.len() >= 4 + 4 * n, "shard {name} truncated id table");
    let ids: Vec<u32> = (0..n)
        .map(|i| u32::from_le_bytes(payload[4 + 4 * i..8 + 4 * i].try_into().unwrap()))
        .collect();
    let ck = Checkpoint::from_bytes(&payload[4 + 4 * n..])?;
    ensure!(ck.n_experts() == n, "shard {name}: id table and frames disagree");
    Ok((ids, ck))
}

/// Crash-consistent restore selection: newest-first over the published
/// manifests with `iter <= max_iter`, returning the first generation whose
/// manifest *and every shard* pass the length+checksum footer check. A
/// torn or corrupt generation is skipped in favor of the previous one.
pub fn select_restore(
    store: &CheckpointStore,
    manifests: &[Manifest],
    max_iter: usize,
) -> Option<Manifest> {
    manifests
        .iter()
        .rev()
        .find(|m| {
            let manifest_ok = match store.load(&manifest_name(m.iter, m.epoch)) {
                Ok(b) => Manifest::from_bytes(&b).is_ok(),
                Err(_) => false,
            };
            m.iter <= max_iter
                && manifest_ok
                && m.shards.iter().all(|(_, f)| load_shard(store, f).is_ok())
        })
        .cloned()
}

// ---------------------------------------------------------------------------
// Deterministic shard trainer
// ---------------------------------------------------------------------------
//
// Every holder of expert `e` runs the identical f32 recurrence
// `w <- w + lr (target_e - w)` per iteration, so replica copies are
// bit-identical to the primary's and the loss of `(e, iter)` is a pure
// function of the applied-update count — the property the conservation
// gate checks against a fault-free reference run.

const LR: f32 = 0.05;

fn init_expert(seed: u64, e: u32, dim: usize) -> Vec<f32> {
    let mut r = Rng::new(seed ^ 0x1111_0000 ^ e as u64);
    (0..dim).map(|_| r.f32()).collect()
}

fn target_of(seed: u64, e: u32, dim: usize) -> Vec<f32> {
    let mut r = Rng::new(seed ^ 0xa5a5_0000 ^ e as u64);
    (0..dim).map(|_| r.f32()).collect()
}

fn apply_update(w: &mut [f32], tgt: &[f32]) {
    for (wi, ti) in w.iter_mut().zip(tgt) {
        *wi += LR * (ti - *wi);
    }
}

fn sq_loss(w: &[f32], tgt: &[f32]) -> f64 {
    let s: f64 = w.iter().zip(tgt).map(|(a, b)| ((b - a) as f64).powi(2)).sum();
    s / w.len() as f64
}

/// The committed loss history of a fault-free run: what any chaotic run
/// must reproduce (up to f64 summation order across reporting shards).
pub fn reference_losses(cfg: &HarnessCfg) -> Vec<f64> {
    let e_total = cfg.e_total();
    let mut ws: Vec<Vec<f32>> =
        (0..e_total as u32).map(|e| init_expert(cfg.seed, e, cfg.expert_dim)).collect();
    let tgts: Vec<Vec<f32>> =
        (0..e_total as u32).map(|e| target_of(cfg.seed, e, cfg.expert_dim)).collect();
    (0..cfg.iters)
        .map(|_| {
            let mut s = 0.0;
            for (w, t) in ws.iter_mut().zip(&tgts) {
                apply_update(w, t);
                s += sq_loss(w, t);
            }
            s
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, Default)]
struct WorkerStats {
    executed: usize,
    beats: usize,
    beat_bytes: usize,
    data_bytes: usize,
    shards: usize,
}

/// Where a blocking worker phase ended.
enum Flow {
    Clean,
    Preempt(EpochPlan),
    Halt,
}

enum Apply {
    Run(usize),
    Preempt(EpochPlan),
    Exit,
    Halt,
}

struct Worker {
    me: usize,
    cfg: HarnessCfg,
    fabric: Arc<Fabric>,
    router: Arc<Router>,
    inbox: Receiver<Message>,
    ctrl: Receiver<Ctrl>,
    coord: Sender<ToCoord>,
    /// This node's scheduled faults (revived workers are born with the
    /// kill that created them already filtered out).
    faults: Vec<NodeFault>,
    consumed_faults: BTreeSet<usize>,
    store: CheckpointStore,
    epoch: u64,
    members: Vec<usize>,
    weights: BTreeMap<u32, Vec<f32>>,
    /// Updates applied per expert — the exactly-once ledger.
    applied: BTreeMap<u32, usize>,
    /// `(expert, iter) -> loss` memo for re-reported iterations.
    memo: BTreeMap<(u32, usize), f64>,
    primaries: Vec<u32>,
    hosted: Vec<u32>,
    stash: Vec<Message>,
    seen: BTreeSet<(u32, usize)>,
    acked: BTreeSet<(u32, usize)>,
    last_beat: Option<Instant>,
    hard_deadline: Instant,
    stats: WorkerStats,
}

impl Worker {
    fn run(mut self) -> WorkerStats {
        let mut pending: Option<EpochPlan> = None;
        'outer: loop {
            let plan = match pending.take() {
                Some(p) => p,
                None => match self.await_plan() {
                    Some(p) => p,
                    None => break 'outer,
                },
            };
            match self.apply_plan(plan) {
                Apply::Run(start) => match self.run_iters(start) {
                    Flow::Preempt(p) => pending = Some(p),
                    Flow::Halt => break 'outer,
                    Flow::Clean => match self.drain() {
                        Flow::Preempt(p) => pending = Some(p),
                        _ => break 'outer,
                    },
                },
                Apply::Preempt(p) => pending = Some(p),
                Apply::Exit | Apply::Halt => break 'outer,
            }
        }
        self.stats
    }

    fn period(&self) -> Duration {
        Duration::from_secs_f64(self.cfg.lease.period_secs)
    }

    fn await_plan(&mut self) -> Option<EpochPlan> {
        loop {
            if Instant::now() >= self.hard_deadline {
                return None;
            }
            match self.ctrl.recv_timeout(self.period()) {
                Ok(Ctrl::Epoch(p)) => return Some(p),
                Ok(Ctrl::Shutdown) | Err(RecvTimeoutError::Disconnected) => return None,
                Err(RecvTimeoutError::Timeout) => {
                    self.beat();
                    self.pump(Duration::from_millis(1));
                }
            }
        }
    }

    fn apply_plan(&mut self, plan: EpochPlan) -> Apply {
        if !plan.members.contains(&self.me) {
            return Apply::Exit; // fenced out — this worker is done
        }
        self.epoch = plan.epoch;
        self.members = plan.members.clone();
        self.stash.clear();
        self.seen.clear();
        self.acked.clear();
        self.primaries =
            plan.assignment.iter().filter(|(_, n)| *n == self.me).map(|(e, _)| *e).collect();
        let hosted: Vec<u32> =
            plan.hosting.iter().filter(|(_, hs)| hs.contains(&self.me)).map(|(e, _)| *e).collect();
        match &plan.restore {
            Restore::Keep => {}
            Restore::Scratch => {
                self.weights.clear();
                self.applied.clear();
                self.memo.clear();
                for &e in &hosted {
                    self.weights.insert(e, init_expert(self.cfg.seed, e, self.cfg.expert_dim));
                    self.applied.insert(e, 0);
                }
            }
            Restore::Manifest(m) => {
                self.weights.clear();
                self.applied.clear();
                self.memo.clear();
                for (_, file) in &m.shards {
                    // the coordinator verified every shard before electing
                    // this manifest; a failure here means the disk mutated
                    // underneath us mid-recovery — fatal, not recoverable
                    let Ok((ids, ck)) = load_shard(&self.store, file) else {
                        return Apply::Halt;
                    };
                    for (i, e) in ids.iter().enumerate() {
                        if hosted.contains(e) {
                            self.weights.insert(*e, ck.restore_expert(i));
                            self.applied.insert(*e, m.iter);
                        }
                    }
                }
            }
        }
        // live migrations run BEFORE dropping no-longer-hosted state: the
        // transfer source may be shedding the very expert it ships
        if !plan.transfers.is_empty() {
            match self.run_transfers(&plan) {
                Flow::Clean => {}
                Flow::Preempt(p) => return Apply::Preempt(p),
                Flow::Halt => return Apply::Halt,
            }
        }
        self.weights.retain(|e, _| hosted.contains(e));
        self.applied.retain(|e, _| hosted.contains(e));
        self.memo.retain(|(e, it), _| hosted.contains(e) && *it >= plan.start_iter);
        self.hosted = hosted;
        Apply::Run(plan.start_iter)
    }

    fn run_iters(&mut self, start: usize) -> Flow {
        let mut iter = start;
        while iter < self.cfg.iters {
            if Instant::now() >= self.hard_deadline {
                return Flow::Halt;
            }
            match self.ctrl.try_recv() {
                Ok(Ctrl::Epoch(p)) => return Flow::Preempt(p),
                Ok(Ctrl::Shutdown) => return Flow::Halt,
                Err(_) => {}
            }
            // scheduled chaos strikes before the iteration executes
            if let Some(f) = self
                .faults
                .iter()
                .find(|f| f.at_iter == iter && !self.consumed_faults.contains(&f.at_iter))
                .copied()
            {
                self.consumed_faults.insert(f.at_iter);
                match f.kind {
                    NodeFaultKind::Kill => return Flow::Halt, // crash: vanish
                    NodeFaultKind::Stall(secs) => {
                        // beats stop for the whole sleep — detection is real
                        std::thread::sleep(Duration::from_secs_f64(secs));
                    }
                }
            }
            self.beat();
            match self.exchange(iter) {
                Flow::Clean => {}
                other => return other,
            }
            // apply + report: skip updates already applied (exactly-once)
            let mut partial = 0.0f64;
            for i in 0..self.hosted.len() {
                let e = self.hosted[i];
                let tgt = target_of(self.cfg.seed, e, self.cfg.expert_dim);
                let w = self.weights.get_mut(&e).expect("hosted expert has state");
                let a = self.applied.entry(e).or_insert(0);
                if *a <= iter {
                    apply_update(w, &tgt);
                    *a = iter + 1;
                }
                let loss = *self.memo.entry((e, iter)).or_insert_with(|| sq_loss(w, &tgt));
                if self.primaries.contains(&e) {
                    partial += loss;
                }
            }
            let _ = self.coord.send(ToCoord::IterDone {
                node: self.me,
                epoch: self.epoch,
                iter,
                loss: partial,
                experts: self.primaries.len(),
            });
            self.stats.executed += 1;
            // keep only the memo window a no-rollback resume can re-read
            let keep_from = (iter + 1).saturating_sub(self.cfg.checkpoint_interval + 4);
            self.memo.retain(|(_, it), _| *it >= keep_from);
            let boundary = iter + 1;
            if boundary % self.cfg.checkpoint_interval == 0 {
                if self.save_shard(boundary).is_err() {
                    return Flow::Halt; // disk gone — the lease will notice
                }
                let _ = self.coord.send(ToCoord::CkptDone {
                    node: self.me,
                    epoch: self.epoch,
                    iter: boundary,
                });
                self.stats.shards += 1;
            }
            iter += 1;
        }
        Flow::Clean
    }

    fn save_shard(&mut self, boundary: usize) -> Result<()> {
        let experts: Vec<Vec<f32>> =
            self.primaries.iter().map(|e| self.weights[e].clone()).collect();
        save_shard(
            &self.store,
            boundary,
            self.epoch,
            self.me,
            &self.primaries,
            &experts,
            self.cfg.expert_dim,
        )?;
        Ok(())
    }

    /// Reliable all-to-all of `payload_bytes` for one iteration: DATA out
    /// to every peer with ack-retry ([`RetryCfg`] backoff), completion
    /// requires every peer's DATA in. Every wait is bounded: preemption is
    /// polled each loop, a lease-timeout's worth of stalling notifies the
    /// coordinator, and the hard deadline guarantees thread exit.
    fn exchange(&mut self, iter: usize) -> Flow {
        let peers: Vec<usize> =
            self.members.iter().copied().filter(|&p| p != self.me).collect();
        if peers.is_empty() {
            return Flow::Clean;
        }
        let dtag = tag(PH_DATA, self.epoch, iter);
        let atag = tag(PH_ACK, self.epoch, iter);
        let payload = vec![0u8; self.cfg.payload_bytes];
        let retry = self.cfg.retry.clone();
        let rto = self.period();
        let now = Instant::now();
        let mut pend: BTreeMap<usize, (u32, Instant)> = BTreeMap::new();
        for &p in &peers {
            self.send_raw(p, dtag, payload.clone());
            pend.insert(p, (1, now + rto));
        }
        let mut have: BTreeSet<usize> = BTreeSet::new();
        let stall_at = now
            + Duration::from_secs_f64(self.cfg.lease.timeout_secs())
            + 2 * self.period();
        let mut stall_notified = false;
        loop {
            if Instant::now() >= self.hard_deadline {
                return Flow::Halt;
            }
            match self.ctrl.try_recv() {
                Ok(Ctrl::Epoch(p)) => return Flow::Preempt(p),
                Ok(Ctrl::Shutdown) => return Flow::Halt,
                Err(_) => {}
            }
            self.beat();
            self.pump(Duration::from_millis(2));
            self.stash.retain(|m| {
                if m.tag == dtag {
                    have.insert(m.from);
                    false
                } else {
                    true
                }
            });
            let acked = &self.acked;
            pend.retain(|p, _| !acked.contains(&(atag, *p)));
            if pend.is_empty() && peers.iter().all(|p| have.contains(p)) {
                return Flow::Clean;
            }
            let t = Instant::now();
            let due: Vec<usize> = pend
                .iter()
                .filter(|(_, (att, next))| t >= *next && (*att as usize) < retry.max_attempts)
                .map(|(p, _)| *p)
                .collect();
            for p in due {
                self.send_raw(p, dtag, payload.clone());
                let entry = pend.get_mut(&p).unwrap();
                entry.0 += 1;
                entry.1 = t + rto + retry.backoff(entry.0);
            }
            if t >= stall_at && !stall_notified {
                stall_notified = true;
                let _ = self.coord.send(ToCoord::Stalled { node: self.me });
            }
        }
    }

    /// Execute the epoch plan's live weight migrations this node is party
    /// to: ship `(expert, applied, weights)` with ack-retry, absorb the
    /// experts addressed to us. Same bounded-wait discipline as exchange.
    fn run_transfers(&mut self, plan: &EpochPlan) -> Flow {
        let outbound: Vec<(u32, usize)> = plan
            .transfers
            .iter()
            .filter(|(_, from, _)| *from == self.me)
            .map(|(e, _, to)| (*e, *to))
            .collect();
        let mut expect: BTreeSet<u32> = plan
            .transfers
            .iter()
            .filter(|(_, _, to)| *to == self.me)
            .map(|(e, _, _)| *e)
            .collect();
        if outbound.is_empty() && expect.is_empty() {
            return Flow::Clean;
        }
        let retry = self.cfg.retry.clone();
        let rto = self.period();
        let now = Instant::now();
        let mut pend: BTreeMap<(u32, usize), (u32, Instant)> = BTreeMap::new();
        for &(e, to) in &outbound {
            self.send_xfer(e, to);
            pend.insert((e, to), (1, now + rto));
        }
        let mut stall_notified = false;
        let stall_at = now
            + Duration::from_secs_f64(self.cfg.lease.timeout_secs())
            + 2 * self.period();
        loop {
            if pend.is_empty() && expect.is_empty() {
                return Flow::Clean;
            }
            if Instant::now() >= self.hard_deadline {
                return Flow::Halt;
            }
            match self.ctrl.try_recv() {
                Ok(Ctrl::Epoch(p)) => return Flow::Preempt(p),
                Ok(Ctrl::Shutdown) => return Flow::Halt,
                Err(_) => {}
            }
            self.beat();
            self.pump(Duration::from_millis(2));
            // absorb arrived expert payloads addressed to us
            let stash = std::mem::take(&mut self.stash);
            for m in stash {
                let (phase, _, idx) = untag(m.tag);
                let e = idx as u32;
                if phase == PH_XFER && expect.remove(&e) {
                    let applied =
                        u32::from_le_bytes(m.bytes[0..4].try_into().unwrap()) as usize;
                    self.weights.insert(e, bytes_to_f32s(&m.bytes[4..]));
                    self.applied.insert(e, applied);
                    self.memo.retain(|(ee, _), _| *ee != e);
                } else {
                    self.stash.push(m);
                }
            }
            let acked = &self.acked;
            let epoch = self.epoch;
            pend.retain(|(e, to), _| {
                !acked.contains(&(tag(PH_XACK, epoch, *e as usize), *to))
            });
            let t = Instant::now();
            let due: Vec<(u32, usize)> = pend
                .iter()
                .filter(|(_, (att, next))| t >= *next && (*att as usize) < retry.max_attempts)
                .map(|(k, _)| *k)
                .collect();
            for (e, to) in due {
                self.send_xfer(e, to);
                let entry = pend.get_mut(&(e, to)).unwrap();
                entry.0 += 1;
                entry.1 = t + rto + retry.backoff(entry.0);
            }
            if t >= stall_at && !stall_notified {
                stall_notified = true;
                let _ = self.coord.send(ToCoord::Stalled { node: self.me });
            }
        }
    }

    fn send_xfer(&mut self, e: u32, to: usize) {
        let mut bytes =
            Vec::with_capacity(4 + 4 * self.cfg.expert_dim);
        let applied = self.applied.get(&e).copied().unwrap_or(0) as u32;
        bytes.extend_from_slice(&applied.to_le_bytes());
        bytes.extend_from_slice(&f32s_to_bytes(
            self.weights.get(&e).expect("transfer source holds the expert"),
        ));
        let t = tag(PH_XFER, self.epoch, e as usize);
        self.send_raw(to, t, bytes);
    }

    /// Idle wait after finishing all iterations: keep beating (the lease
    /// stays live), keep acking peers that are still behind, and stay
    /// preemptible — a late recovery can still roll this worker back.
    fn drain(&mut self) -> Flow {
        loop {
            if Instant::now() >= self.hard_deadline {
                return Flow::Halt;
            }
            match self.ctrl.recv_timeout(Duration::from_millis(10)) {
                Ok(Ctrl::Epoch(p)) => return Flow::Preempt(p),
                Ok(Ctrl::Shutdown) | Err(RecvTimeoutError::Disconnected) => return Flow::Halt,
                Err(RecvTimeoutError::Timeout) => {
                    self.beat();
                    self.pump(Duration::from_millis(1));
                }
            }
        }
    }

    /// Send one heartbeat per lease period. Beats are exempt from
    /// per-message chaos on purpose: missed-beat detection is exercised by
    /// the *node* faults (a kill or stall silences the beat source
    /// entirely), and keeping beat delivery reliable is what makes lease
    /// expiries a function of the schedule rather than of wall-clock
    /// alignment between drop patterns and detection windows — the
    /// determinism contract the soak gate diffs logs under.
    fn beat(&mut self) {
        if self.last_beat.is_some_and(|t| t.elapsed() < self.period()) {
            return;
        }
        self.last_beat = Some(Instant::now());
        self.stats.beats += 1;
        self.stats.beat_bytes += self.cfg.lease.beat_bytes as usize;
        let _ = self.coord.send(ToCoord::Beat { node: self.me });
    }

    /// Drain the data inbox for up to `wait`, acking DATA/XFER for the
    /// current epoch (stale-epoch traffic is dropped unacked — the sender
    /// will retry after it adopts the new epoch) and recording acks.
    fn pump(&mut self, wait: Duration) {
        let deadline = Instant::now() + wait;
        loop {
            let now = Instant::now();
            if now >= deadline {
                while let Ok(m) = self.inbox.try_recv() {
                    self.sort_in(m);
                }
                return;
            }
            match self.inbox.recv_timeout(deadline - now) {
                Ok(m) => self.sort_in(m),
                Err(_) => return,
            }
        }
    }

    fn sort_in(&mut self, m: Message) {
        let (phase, ep, idx) = untag(m.tag);
        if ep != epoch_low(self.epoch) {
            return; // fenced: stale or future epoch
        }
        match phase {
            PH_DATA | PH_XFER => {
                let ack_phase = if phase == PH_DATA { PH_ACK } else { PH_XACK };
                let from = m.from;
                self.send_raw(from, tag(ack_phase, self.epoch, idx), Vec::new());
                if self.seen.insert((m.tag, m.from)) {
                    self.stash.push(m); // deduplicated: retransmits ack only
                }
            }
            PH_ACK | PH_XACK => {
                self.acked.insert((m.tag, m.from));
            }
            _ => {}
        }
    }

    /// Put bytes on the wire: pays fabric pacing, consults the chaos
    /// interposer, and only delivers to the receiver's inbox if the
    /// message survived. Returns delivery for symmetry with
    /// `WorkerCtx::send_tracked`; the ack layer is what makes it reliable.
    fn send_raw(&mut self, to: usize, tag: u32, bytes: Vec<u8>) -> bool {
        self.stats.data_bytes += bytes.len() + FRAME_BYTES;
        if !self.fabric.transmit_interposed(self.me, to, bytes.len() + FRAME_BYTES) {
            return false;
        }
        self.router.deliver(to, Message { from: self.me, tag, bytes });
        true
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// A membership re-solve recorded at recovery/grow time.
#[derive(Clone, Debug)]
pub struct Replan {
    pub epoch: u64,
    pub survivors: usize,
    /// The joint solver's 4D config on the re-shaped cluster (`None` when
    /// no candidate is feasible, e.g. a lone survivor).
    pub config: Option<ParallelismConfig>,
}

/// Outcome of one harness run.
#[derive(Clone, Debug)]
pub struct HarnessReport {
    pub committed: usize,
    /// Committed per-iteration loss history (exactly-once: matches a
    /// fault-free run of the same seed).
    pub losses: Vec<f64>,
    pub epochs: u64,
    pub recoveries: usize,
    pub lease_expiries: usize,
    /// Published (all-member) checkpoint manifests.
    pub checkpoints: usize,
    /// Recoveries that restored from a durable manifest.
    pub restores: usize,
    /// Committed-front regressions summed over recoveries (iterations the
    /// membership had to walk again).
    pub redone_iters: usize,
    /// Worker-iteration executions summed over all threads.
    pub executed_iters: usize,
    pub stall_backstops: usize,
    pub heartbeats: usize,
    pub heartbeat_bytes: usize,
    pub data_bytes: usize,
    pub wall_secs: f64,
    /// Wall seconds from each recovery broadcast to its first new commit.
    pub recovery_secs: Vec<f64>,
    pub replans: Vec<Replan>,
    pub log: EventLog,
}

struct Coordinator {
    cfg: HarnessCfg,
    schedule: ChaosSchedule,
    fabric: Arc<Fabric>,
    router: Arc<Router>,
    store: CheckpointStore,
    coord_tx: Sender<ToCoord>,
    coord_rx: Receiver<ToCoord>,
    ctrls: BTreeMap<usize, Sender<Ctrl>>,
    handles: Vec<JoinHandle<WorkerStats>>,
    members: Vec<usize>,
    epoch: u64,
    committed: usize,
    losses: Vec<f64>,
    /// Per-member completed-iteration high-water mark (current epoch).
    done: BTreeMap<usize, usize>,
    assignment: Vec<(u32, usize)>,
    hosting: Vec<(u32, Vec<usize>)>,
    /// Per-iteration (loss sum, experts reported) accumulator.
    loss_acc: BTreeMap<usize, (f64, usize)>,
    /// Per-boundary set of members whose shard landed (current epoch).
    ckpt_acc: BTreeMap<usize, BTreeSet<usize>>,
    manifests: Vec<Manifest>,
    last_beat: BTreeMap<usize, Instant>,
    /// Completed-iteration count of each expired node at detection.
    dead_done: BTreeMap<usize, usize>,
    revived: BTreeSet<usize>,
    log: EventLog,
    lease_expiries: usize,
    recoveries: usize,
    restores: usize,
    redone: usize,
    stall_backstops: usize,
    published: usize,
    recovery_t0: Option<Instant>,
    recovery_secs: Vec<f64>,
    replans: Vec<Replan>,
    /// Nodes-as-DCs cluster tracking the live membership for the solver.
    planner_cluster: ClusterSpec,
    /// Node id at each surviving DC position of `planner_cluster`.
    cluster_order: Vec<usize>,
    t0: Instant,
}

impl Coordinator {
    fn new(cfg: HarnessCfg, schedule: ChaosSchedule) -> Result<Self> {
        let cluster = presets::dcs_x_gpus(cfg.nodes, 1, cfg.inter_gbps, cfg.intra_gbps);
        let mut fabric = Fabric::new(cluster.clone(), cfg.time_scale);
        if schedule.drop_p > 0.0 || schedule.delay_p > 0.0 {
            fabric = fabric.with_interposer(Arc::new(schedule.interposer()));
        }
        let store = CheckpointStore::open(cfg.store_dir.clone())?;
        let (coord_tx, coord_rx) = channel();
        let mut co = Self {
            members: (0..cfg.nodes).collect(),
            cluster_order: (0..cfg.nodes).collect(),
            planner_cluster: cluster,
            fabric: Arc::new(fabric),
            router: Arc::new(Router::new(cfg.nodes)),
            store,
            coord_tx,
            coord_rx,
            ctrls: BTreeMap::new(),
            handles: Vec::new(),
            epoch: 0,
            committed: 0,
            losses: Vec::new(),
            done: (0..cfg.nodes).map(|n| (n, 0)).collect(),
            assignment: Vec::new(),
            hosting: Vec::new(),
            loss_acc: BTreeMap::new(),
            ckpt_acc: BTreeMap::new(),
            manifests: Vec::new(),
            last_beat: BTreeMap::new(),
            dead_done: BTreeMap::new(),
            revived: BTreeSet::new(),
            log: EventLog::default(),
            lease_expiries: 0,
            recoveries: 0,
            restores: 0,
            redone: 0,
            stall_backstops: 0,
            published: 0,
            recovery_t0: None,
            recovery_secs: Vec::new(),
            replans: Vec::new(),
            t0: Instant::now(),
            cfg,
            schedule,
        };
        for node in 0..co.cfg.nodes {
            co.spawn(node, None)?;
        }
        let (assignment, hosting) = co.layout();
        co.assignment = assignment;
        co.hosting = hosting;
        co.log.push(Event::EpochStart {
            epoch: 0,
            members: co.members.clone(),
            start_iter: 0,
        });
        co.broadcast(0, Restore::Scratch, Vec::new());
        Ok(co)
    }

    /// Start (or restart, for revivals) the worker thread for `node`.
    /// `born` is the iteration of the kill that created a revived worker —
    /// its own faults are filtered to strictly later iterations.
    fn spawn(&mut self, node: usize, born: Option<usize>) -> Result<()> {
        let (data_tx, data_rx) = channel();
        self.router.install(node, data_tx);
        let (ctrl_tx, ctrl_rx) = channel();
        self.ctrls.insert(node, ctrl_tx);
        let w = Worker {
            me: node,
            cfg: self.cfg.clone(),
            fabric: self.fabric.clone(),
            router: self.router.clone(),
            inbox: data_rx,
            ctrl: ctrl_rx,
            coord: self.coord_tx.clone(),
            faults: self.schedule.faults_for(node, born),
            consumed_faults: BTreeSet::new(),
            store: CheckpointStore::open(self.cfg.store_dir.clone())?,
            epoch: 0,
            members: Vec::new(),
            weights: BTreeMap::new(),
            applied: BTreeMap::new(),
            memo: BTreeMap::new(),
            primaries: Vec::new(),
            hosted: Vec::new(),
            stash: Vec::new(),
            seen: BTreeSet::new(),
            acked: BTreeSet::new(),
            last_beat: None,
            hard_deadline: self.t0
                + Duration::from_secs_f64(2.0 * self.cfg.watchdog_secs),
            stats: WorkerStats::default(),
        };
        let h = std::thread::Builder::new()
            .name(format!("harness-{node}"))
            .spawn(move || w.run())
            .context("spawning harness worker")?;
        self.handles.push(h);
        self.last_beat.insert(node, Instant::now());
        Ok(())
    }

    /// Round-robin expert placement over the current membership: expert `e`
    /// is primaried at position `e % m`, replicated on the next
    /// `replicas - 1` positions (copy order = promotion order).
    fn layout(&self) -> (Vec<(u32, usize)>, Vec<(u32, Vec<usize>)>) {
        let m = self.members.len();
        let r = self.cfg.replicas.min(m);
        let mut assignment = Vec::new();
        let mut hosting = Vec::new();
        for e in 0..self.cfg.e_total() as u32 {
            let pos = e as usize % m;
            let holders: Vec<usize> =
                (0..r).map(|j| self.members[(pos + j) % m]).collect();
            assignment.push((e, holders[0]));
            hosting.push((e, holders));
        }
        (assignment, hosting)
    }

    /// Send the current epoch plan to EVERY worker that ever ran — members
    /// or not. Fencing: an evicted worker seeing a membership it is not in
    /// exits instead of retrying into peers that no longer answer it.
    fn broadcast(&self, start_iter: usize, restore: Restore, transfers: Vec<(u32, usize, usize)>) {
        for tx in self.ctrls.values() {
            let _ = tx.send(Ctrl::Epoch(EpochPlan {
                epoch: self.epoch,
                members: self.members.clone(),
                start_iter,
                assignment: self.assignment.clone(),
                hosting: self.hosting.clone(),
                restore: restore.clone(),
                transfers: transfers.clone(),
            }));
        }
    }

    fn handle(&mut self, msg: ToCoord) {
        match msg {
            ToCoord::Beat { node } => {
                if self.members.contains(&node) {
                    self.last_beat.insert(node, Instant::now());
                }
            }
            ToCoord::IterDone { node, epoch, iter, loss, experts } => {
                if epoch != self.epoch || !self.members.contains(&node) {
                    return; // fenced: a previous epoch's report
                }
                let d = self.done.entry(node).or_insert(0);
                *d = (*d).max(iter + 1);
                if iter >= self.committed && iter < self.cfg.iters {
                    let acc = self.loss_acc.entry(iter).or_insert((0.0, 0));
                    acc.0 += loss;
                    acc.1 += experts;
                }
                self.advance();
            }
            ToCoord::CkptDone { node, epoch, iter } => {
                if epoch != self.epoch || !self.members.contains(&node) {
                    return;
                }
                self.ckpt_acc.entry(iter).or_default().insert(node);
                self.try_publish(iter);
            }
            ToCoord::Stalled { .. } => self.stall_backstops += 1,
        }
    }

    /// Advance the commit front: iteration `c` commits once every member
    /// reported past it and all `e_total` expert losses accumulated.
    fn advance(&mut self) {
        while self.committed < self.cfg.iters {
            let c = self.committed;
            let all_past =
                self.members.iter().all(|m| self.done.get(m).copied().unwrap_or(0) > c);
            let full =
                self.loss_acc.get(&c).map_or(false, |(_, n)| *n == self.cfg.e_total());
            if !(all_past && full) {
                return;
            }
            let (sum, _) = self.loss_acc.remove(&c).unwrap();
            self.losses.push(sum);
            self.committed += 1;
            if let Some(t) = self.recovery_t0.take() {
                self.recovery_secs.push(t.elapsed().as_secs_f64());
            }
            // revivals key on exact commit crossings: `committed` only
            // moves in +1 steps here, so a pending revival fires the first
            // time the front *equals* its bound — a deterministic instant,
            // unlike detection-time commit values which wobble with acks
            self.check_revivals();
        }
    }

    fn check_revivals(&mut self) {
        if self.committed >= self.cfg.iters {
            return;
        }
        let due: Vec<NodeFault> = self
            .schedule
            .node_faults
            .iter()
            .filter(|f| {
                matches!(f.kind, NodeFaultKind::Kill)
                    && f.revive_at.map_or(false, |r| r <= self.committed)
                    && !self.members.contains(&f.node)
                    && !self.revived.contains(&f.node)
            })
            .copied()
            .collect();
        for f in due {
            self.revived.insert(f.node);
            // a spawn failure forfeits the revival; the run continues on
            // the surviving membership
            let _ = self.grow(f.node, f.at_iter);
        }
    }

    /// Re-admit a revived node: new epoch, grown membership, re-laid-out
    /// experts shipped to their new holders over the data plane, no
    /// rollback (survivors keep live state).
    fn grow(&mut self, node: usize, killed_at: usize) -> Result<()> {
        self.epoch += 1;
        self.recoveries += 1;
        self.spawn(node, Some(killed_at))?;
        let old_hosting = self.hosting.clone();
        self.members.push(node);
        self.members.sort_unstable();
        let (assignment, hosting) = self.layout();
        // each expert reaches its new holders from the old primary (the
        // sender may itself be shedding the expert — workers migrate
        // before dropping state)
        let mut transfers = Vec::new();
        for ((e, new_holders), (_, old_holders)) in hosting.iter().zip(&old_hosting) {
            for &h in new_holders {
                if !old_holders.contains(&h) {
                    transfers.push((*e, old_holders[0], h));
                }
            }
        }
        self.assignment = assignment;
        self.hosting = hosting;
        let start = self.committed;
        self.done = self.members.iter().map(|&m| (m, start)).collect();
        self.loss_acc.clear();
        self.ckpt_acc.clear();
        let now = Instant::now();
        for &m in &self.members {
            self.last_beat.insert(m, now);
        }
        self.log.push(Event::Recovery {
            epoch: self.epoch,
            mode: RecoveryMode::Elastic,
            dead: vec![],
            joined: vec![node],
            start_iter: start,
            restored_from: None,
        });
        self.log.push(Event::EpochStart {
            epoch: self.epoch,
            members: self.members.clone(),
            start_iter: start,
        });
        self.broadcast(start, Restore::Keep, transfers);
        self.planner_cluster = presets::dcs_x_gpus(
            self.members.len(),
            1,
            self.cfg.inter_gbps,
            self.cfg.intra_gbps,
        );
        self.cluster_order = self.members.clone();
        self.record_replan();
        self.recovery_t0 = Some(Instant::now());
        Ok(())
    }

    fn expired(&self) -> Vec<usize> {
        let timeout = Duration::from_secs_f64(self.cfg.lease.timeout_secs());
        self.members
            .iter()
            .copied()
            .filter(|m| self.last_beat.get(m).map_or(true, |t| t.elapsed() > timeout))
            .collect()
    }

    fn check_leases(&mut self) -> Result<()> {
        if self.expired().is_empty() {
            return Ok(());
        }
        // settle: drain in-flight beats for two periods before confirming —
        // a beat racing the check clears its lease
        let settle_until =
            Instant::now() + 2 * Duration::from_secs_f64(self.cfg.lease.period_secs);
        loop {
            let now = Instant::now();
            if now >= settle_until {
                break;
            }
            match self.coord_rx.recv_timeout(settle_until - now) {
                Ok(m) => self.handle(m),
                Err(_) => break,
            }
        }
        let dead = self.expired();
        if dead.is_empty() {
            return Ok(());
        }
        for &d in &dead {
            let done = self.done.get(&d).copied().unwrap_or(0);
            self.dead_done.insert(d, done);
            self.log.push(Event::LeaseExpired { epoch: self.epoch, node: d, done });
            self.lease_expiries += 1;
        }
        self.recover(&dead)
    }

    /// Evict `dead` and resume under the configured [`RecoveryMode`]:
    ///
    /// | mode            | rollback                         | restore        |
    /// |-----------------|----------------------------------|----------------|
    /// | ReplicaFailover (covered) | none — promote holders | live state     |
    /// | Elastic         | last verified manifest `<= B`    | durable shards |
    /// | StaticRestart   | everything                       | scratch init   |
    ///
    /// `B = floor((min_dead_done - 1) / interval) * interval` — derived from
    /// the dead nodes' own progress, a schedule-deterministic quantity.
    /// An uncovered failover falls back to (and logs) Elastic.
    fn recover(&mut self, dead: &[usize]) -> Result<()> {
        self.recoveries += 1;
        let pre = self.committed;
        self.members.retain(|m| !dead.contains(m));
        ensure!(!self.members.is_empty(), "every node's lease expired — no survivors");
        let min_dead_done = dead
            .iter()
            .filter_map(|d| self.dead_done.get(d))
            .copied()
            .min()
            .unwrap_or(0);
        let covered = self.cfg.recovery == RecoveryMode::ReplicaFailover
            && self
                .hosting
                .iter()
                .all(|(_, hs)| hs.iter().any(|h| self.members.contains(h)));
        let (start, restore, restored_from, exec_mode) = if covered {
            // promote the next surviving holder in copy order; the commit
            // front stands. Resume one iteration early: a survivor may be
            // wedged in the exchange *before* its compute of that
            // iteration (the victim died owing it an ack), and re-running
            // it is harmless for everyone else — applied-counts skip the
            // update and the memoized loss is re-reported.
            for (_, hs) in &mut self.hosting {
                hs.retain(|h| self.members.contains(h));
            }
            for ((_, hs), a) in self.hosting.iter().zip(self.assignment.iter_mut()) {
                a.1 = hs[0];
            }
            (
                min_dead_done.saturating_sub(1),
                Restore::Keep,
                None,
                RecoveryMode::ReplicaFailover,
            )
        } else if self.cfg.recovery == RecoveryMode::StaticRestart {
            self.committed = 0;
            self.losses.clear();
            let (assignment, hosting) = self.layout();
            self.assignment = assignment;
            self.hosting = hosting;
            (0, Restore::Scratch, None, RecoveryMode::StaticRestart)
        } else {
            let target = if min_dead_done == 0 {
                0
            } else {
                ((min_dead_done - 1) / self.cfg.checkpoint_interval)
                    * self.cfg.checkpoint_interval
            };
            let picked = select_restore(&self.store, &self.manifests, target);
            let (assignment, hosting) = self.layout();
            self.assignment = assignment;
            self.hosting = hosting;
            match picked {
                Some(m) => {
                    self.committed = m.iter;
                    self.losses.truncate(m.iter);
                    self.restores += 1;
                    let it = m.iter;
                    (it, Restore::Manifest(m), Some(it), RecoveryMode::Elastic)
                }
                None => {
                    self.committed = 0;
                    self.losses.clear();
                    (0, Restore::Scratch, None, RecoveryMode::Elastic)
                }
            }
        };
        self.redone += pre.saturating_sub(start);
        self.epoch += 1;
        self.done = self.members.iter().map(|&m| (m, start)).collect();
        self.loss_acc.clear();
        self.ckpt_acc.clear();
        let now = Instant::now();
        for &m in &self.members {
            self.last_beat.insert(m, now);
        }
        for d in dead {
            self.last_beat.remove(d);
        }
        self.log.push(Event::Recovery {
            epoch: self.epoch,
            mode: exec_mode,
            dead: dead.to_vec(),
            joined: vec![],
            start_iter: start,
            restored_from,
        });
        self.log.push(Event::EpochStart {
            epoch: self.epoch,
            members: self.members.clone(),
            start_iter: start,
        });
        self.broadcast(start, restore, Vec::new());
        // re-solve parallelism on the shrunk cluster (simulation mirror:
        // shrink_cluster + the joint solver)
        let lost: BTreeSet<usize> = dead
            .iter()
            .filter_map(|d| self.cluster_order.iter().position(|n| n == d))
            .collect();
        if let Ok(shrunk) = shrink_cluster(&self.planner_cluster, &lost) {
            self.planner_cluster = shrunk;
            self.cluster_order.retain(|n| !dead.contains(n));
            self.record_replan();
        }
        self.recovery_t0 = Some(Instant::now());
        Ok(())
    }

    fn record_replan(&mut self) {
        let w = MoEWorkload {
            tokens_per_gpu: 64,
            hidden: 32,
            ffn: 64,
            experts_per_gpu: self.cfg.experts_per_node,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let pe_tx = (self.cfg.expert_dim * 4) as f64;
        let config = solve_joint(&self.planner_cluster, &w, &GpuSpec::a800(), pe_tx)
            .ok()
            .map(|c| c.config);
        self.replans.push(Replan {
            epoch: self.epoch,
            survivors: self.members.len(),
            config,
        });
    }

    /// Two-phase publish: once every current member's shard for boundary
    /// `b` landed, write the manifest naming them. A crash between shards
    /// and manifest leaves no manifest — the generation never existed.
    fn try_publish(&mut self, b: usize) {
        let complete = self
            .ckpt_acc
            .get(&b)
            .map_or(false, |got| self.members.iter().all(|m| got.contains(m)));
        if !complete {
            return;
        }
        self.ckpt_acc.remove(&b);
        let m = Manifest {
            iter: b,
            epoch: self.epoch,
            shards: self
                .members
                .iter()
                .map(|&n| (n, shard_name(b, self.epoch, n)))
                .collect(),
        };
        if self.store.save(&manifest_name(b, self.epoch), &m.to_bytes()).is_ok() {
            self.manifests.push(m);
            self.log.push(Event::CheckpointSaved { epoch: self.epoch, iter: b });
            self.published += 1;
        }
    }

    fn drive(&mut self) -> Result<()> {
        let tick =
            Duration::from_secs_f64((self.cfg.lease.period_secs / 4.0).max(0.002));
        loop {
            ensure!(
                self.t0.elapsed().as_secs_f64() <= self.cfg.watchdog_secs,
                "harness watchdog: no finish within {}s (committed {}/{})",
                self.cfg.watchdog_secs,
                self.committed,
                self.cfg.iters
            );
            if let Ok(m) = self.coord_rx.recv_timeout(tick) {
                self.handle(m);
            }
            while let Ok(m) = self.coord_rx.try_recv() {
                self.handle(m);
            }
            if self.committed >= self.cfg.iters {
                return Ok(());
            }
            self.check_leases()?;
        }
    }

    fn shutdown(&self) {
        for tx in self.ctrls.values() {
            let _ = tx.send(Ctrl::Shutdown);
        }
    }

    fn join(&mut self) -> Result<WorkerStats> {
        let mut agg = WorkerStats::default();
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(s) => {
                    agg.executed += s.executed;
                    agg.beats += s.beats;
                    agg.beat_bytes += s.beat_bytes;
                    agg.data_bytes += s.data_bytes;
                    agg.shards += s.shards;
                }
                Err(_) => bail!("a harness worker panicked"),
            }
        }
        Ok(agg)
    }

    fn finish(mut self) -> Result<HarnessReport> {
        // grace window for the final boundary's shards to land and publish
        let grace = Instant::now() + Duration::from_secs(1);
        while !self.ckpt_acc.is_empty() && Instant::now() < grace {
            if let Ok(m) = self.coord_rx.recv_timeout(Duration::from_millis(5)) {
                self.handle(m);
            }
        }
        self.log.push(Event::Finished {
            epoch: self.epoch,
            committed: self.committed,
        });
        self.shutdown();
        let stats = self.join()?;
        Ok(HarnessReport {
            committed: self.committed,
            losses: self.losses,
            epochs: self.epoch + 1,
            recoveries: self.recoveries,
            lease_expiries: self.lease_expiries,
            checkpoints: self.published,
            restores: self.restores,
            redone_iters: self.redone,
            executed_iters: stats.executed,
            stall_backstops: self.stall_backstops,
            heartbeats: stats.beats,
            heartbeat_bytes: stats.beat_bytes,
            data_bytes: stats.data_bytes,
            wall_secs: self.t0.elapsed().as_secs_f64(),
            recovery_secs: self.recovery_secs,
            replans: self.replans,
            log: self.log,
        })
    }
}

/// Execute one chaos-harness run to completion (or watchdog abort).
///
/// The schedule is first nudged off checkpoint boundaries
/// ([`ChaosSchedule::aligned_to`]) so fault/publication races cannot make
/// the event log timing-dependent. Returns the report once all
/// `cfg.iters` iterations committed; errors (never hangs) on watchdog
/// expiry, worker panic, or total membership loss.
pub fn run(cfg: &HarnessCfg, schedule: &ChaosSchedule) -> Result<HarnessReport> {
    cfg.validate()?;
    let schedule =
        schedule.clone().aligned_to(cfg.checkpoint_interval, cfg.iters);
    for f in &schedule.node_faults {
        ensure!(
            f.node < cfg.nodes,
            "fault targets node {} but the run has {}",
            f.node,
            cfg.nodes
        );
    }
    let mut co = Coordinator::new(cfg.clone(), schedule)?;
    match co.drive() {
        Ok(()) => co.finish(),
        Err(e) => {
            // bounded teardown even on abort: workers poll the control
            // channel and hard-stop at 2x the watchdog regardless
            co.shutdown();
            let _ = co.join();
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> CheckpointStore {
        let dir = std::env::temp_dir()
            .join(format!("hybrid_ep_harness_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointStore::open(dir).expect("open store")
    }

    #[test]
    fn tags_round_trip_phase_epoch_and_index() {
        for (phase, epoch, idx) in
            [(PH_DATA, 0u64, 0usize), (PH_ACK, 4095, 65535), (PH_XFER, 7, 123), (PH_XACK, 4099, 42)]
        {
            let t = tag(phase, epoch, idx);
            assert_eq!(untag(t), (phase, epoch_low(epoch), idx));
        }
    }

    #[test]
    fn cfg_validation_rejects_degenerate_runs() {
        let ok = HarnessCfg::quick(4, 8, 1, std::env::temp_dir());
        assert!(ok.validate().is_ok());
        let mut c = ok.clone();
        c.nodes = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.replicas = 9; // > nodes
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.replicas = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.checkpoint_interval = 0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.time_scale = 0.0;
        assert!(c.validate().is_err());
        let mut c = ok.clone();
        c.watchdog_secs = f64::INFINITY;
        assert!(c.validate().is_err());
        let mut c = ok;
        c.retry.max_attempts = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn manifest_codec_round_trips_and_rejects_garbage() {
        let m = Manifest {
            iter: 8,
            epoch: 2,
            shards: vec![(0, shard_name(8, 2, 0)), (3, shard_name(8, 2, 3))],
        };
        assert_eq!(Manifest::from_bytes(&m.to_bytes()).unwrap(), m);
        assert!(Manifest::from_bytes(b"\n\n").is_err());
        assert!(Manifest::from_bytes(b"8\n2\n").is_err(), "no shards");
        assert!(Manifest::from_bytes(b"8\n2\nmalformed-line\n").is_err());
    }

    #[test]
    fn shards_restore_bit_exact() {
        let store = tmp_store("shard");
        let experts: Vec<Vec<f32>> = (0..3u32).map(|e| init_expert(9, e, 16)).collect();
        let name = save_shard(&store, 4, 0, 1, &[5, 7, 9], &experts, 16).unwrap();
        let (ids, ck) = load_shard(&store, &name).unwrap();
        assert_eq!(ids, vec![5, 7, 9]);
        for (i, w) in experts.iter().enumerate() {
            assert_eq!(&ck.restore_expert(i), w, "expert {i} not bit-exact");
        }
    }

    #[test]
    fn select_restore_skips_torn_generations() {
        let store = tmp_store("torn");
        let dim = 8;
        let mut manifests = Vec::new();
        for b in [4usize, 8] {
            let mut shards = Vec::new();
            for node in 0..2usize {
                let experts: Vec<Vec<f32>> =
                    (0..2u32).map(|e| init_expert(1, e, dim)).collect();
                let ids = [node as u32 * 2, node as u32 * 2 + 1];
                shards.push((node, save_shard(&store, b, 0, node, &ids, &experts, dim).unwrap()));
            }
            let m = Manifest { iter: b, epoch: 0, shards };
            store.save(&manifest_name(b, 0), &m.to_bytes()).unwrap();
            manifests.push(m);
        }
        // newest generation first, bounded by max_iter
        assert_eq!(select_restore(&store, &manifests, 8).unwrap().iter, 8);
        assert_eq!(select_restore(&store, &manifests, 7).unwrap().iter, 4);
        assert!(select_restore(&store, &manifests, 3).is_none());
        // tear a generation-8 shard on disk: fall back to generation 4
        let victim = store.path_of(&shard_name(8, 0, 1));
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 3]).unwrap();
        assert_eq!(select_restore(&store, &manifests, 8).unwrap().iter, 4);
        // tear generation 4's manifest too: nothing survives
        let mpath = store.path_of(&manifest_name(4, 0));
        let bytes = std::fs::read(&mpath).unwrap();
        std::fs::write(&mpath, &bytes[..bytes.len() - 2]).unwrap();
        assert!(select_restore(&store, &manifests, 8).is_none());
    }

    #[test]
    fn reference_losses_are_deterministic_and_decreasing() {
        let cfg = HarnessCfg::quick(3, 12, 77, std::env::temp_dir());
        let a = reference_losses(&cfg);
        assert_eq!(a, reference_losses(&cfg));
        assert_eq!(a.len(), 12);
        for w in a.windows(2) {
            assert!(w[1] < w[0], "losses must strictly decrease: {w:?}");
        }
        let other = HarnessCfg::quick(3, 12, 78, std::env::temp_dir());
        assert_ne!(reference_losses(&other), a);
    }
}

