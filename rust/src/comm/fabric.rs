//! The cluster fabric: hierarchical throttled links + message delivery.
//!
//! Maps a [`ClusterSpec`] to per-container [`Link`]s exactly like the
//! flow simulator does (egress/ingress at the bottleneck level), but with
//! real wall-clock pacing. `time_scale` > 1 shrinks sleep times uniformly so
//! demos of multi-second paper iterations finish quickly while preserving
//! all bandwidth *ratios*.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::{ClusterSpec, Multilevel};
use crate::comm::throttle::Link;

pub struct Fabric {
    pub cluster: ClusterSpec,
    ml: Multilevel,
    /// `links[level][container]` = (egress, ingress)
    links: Vec<Vec<(Arc<Link>, Arc<Link>)>>,
    pub time_scale: f64,
}

impl Fabric {
    pub fn new(cluster: ClusterSpec, time_scale: f64) -> Self {
        assert!(time_scale > 0.0);
        let ml = cluster.multilevel();
        let mut links = Vec::new();
        for (l, spec) in cluster.levels.iter().enumerate() {
            let containers: usize = ml.scaling()[..=l].iter().product();
            let latency = Duration::from_secs_f64(spec.latency / time_scale);
            links.push(
                (0..containers)
                    .map(|_| {
                        (
                            Arc::new(Link::new(spec.bandwidth * time_scale, latency)),
                            Arc::new(Link::new(spec.bandwidth * time_scale, latency)),
                        )
                    })
                    .collect(),
            );
        }
        Self { cluster, ml, links, time_scale }
    }

    pub fn gpus(&self) -> usize {
        self.ml.total_gpus()
    }

    /// Block the caller for the transfer time of `bytes` from `src` to `dst`
    /// (shared-link contention included). Loopback returns immediately.
    pub fn transmit(&self, src: usize, dst: usize, bytes: usize) {
        let Some(level) = self.cluster.bottleneck_level(src, dst) else {
            return;
        };
        let e = &self.links[level][self.ml.worker_of(src, level)].0;
        let i = &self.links[level][self.ml.worker_of(dst, level)].1;
        Link::transmit_multi(&[e, i], bytes);
    }

    /// Wall-clock seconds → simulated seconds (undo `time_scale`).
    pub fn to_sim_time(&self, wall: f64) -> f64 {
        wall * self.time_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use std::time::Instant;

    #[test]
    fn cross_dc_slower_than_intra() {
        let f = Fabric::new(presets::dcs_x_gpus(2, 2, 10.0, 1280.0), 10.0);
        let bytes = 40_000_000; // 3.2 ms inter vs 0.025 ms intra at scale 10
        let t0 = Instant::now();
        f.transmit(0, 1, bytes); // intra
        let intra = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        f.transmit(0, 2, bytes); // inter
        let inter = t1.elapsed().as_secs_f64();
        assert!(inter > 4.0 * intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn loopback_free() {
        let f = Fabric::new(presets::cluster_s(), 1.0);
        let t0 = Instant::now();
        f.transmit(3, 3, 100_000_000);
        assert!(t0.elapsed().as_secs_f64() < 0.01);
    }

    #[test]
    fn time_scale_speeds_up() {
        let slow = Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 1.0);
        let fast = Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 50.0);
        let bytes = 2_000_000; // 1.6 ms at 10 Gbps
        let t0 = Instant::now();
        slow.transmit(0, 1, bytes);
        let t_slow = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        fast.transmit(0, 1, bytes);
        let t_fast = t1.elapsed().as_secs_f64();
        assert!(t_slow > 3.0 * t_fast, "scale 50 should be much faster: {t_slow} vs {t_fast}");
    }
}
