//! The cluster fabric: hierarchical throttled links + message delivery.
//!
//! Maps a [`ClusterSpec`] to per-container [`Link`]s exactly like the
//! flow simulator does (egress/ingress at the bottleneck level), but with
//! real wall-clock pacing. `time_scale` > 1 shrinks sleep times uniformly so
//! demos of multi-second paper iterations finish quickly while preserving
//! all bandwidth *ratios*.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::{ClusterSpec, Multilevel};
use crate::comm::throttle::Link;

/// Per-message ruling from a fabric [`Interposer`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verdict {
    Deliver,
    /// The bytes leave the sender's NIC (pacing is still paid) but never
    /// arrive: the caller must *not* hand the message to the receiver.
    Drop,
    /// Deliver after an extra one-way delay of this many **simulated**
    /// seconds (scaled down by `time_scale` like link latency).
    Delay(f64),
}

/// A chaos hook consulted once per interposed transfer, in the sender's
/// program order per `(src, dst)` pair. `seq` is the per-pair message
/// sequence number, so seeded implementations rule deterministically
/// regardless of cross-pair thread interleaving (see `runtime::chaos`).
pub trait Interposer: Send + Sync {
    fn verdict(&self, src: usize, dst: usize, bytes: usize, seq: u64) -> Verdict;
}

pub struct Fabric {
    pub cluster: ClusterSpec,
    ml: Multilevel,
    /// `links[level][container]` = (egress, ingress)
    links: Vec<Vec<(Arc<Link>, Arc<Link>)>>,
    pub time_scale: f64,
    interposer: Option<Arc<dyn Interposer>>,
    /// Per-`(src, dst)` sequence counters for [`transmit_interposed`](Self::transmit_interposed).
    seqs: Mutex<BTreeMap<(usize, usize), u64>>,
}

impl Fabric {
    pub fn new(cluster: ClusterSpec, time_scale: f64) -> Self {
        assert!(time_scale > 0.0);
        let ml = cluster.multilevel();
        let mut links = Vec::new();
        for (l, spec) in cluster.levels.iter().enumerate() {
            let containers: usize = ml.scaling()[..=l].iter().product();
            let latency = Duration::from_secs_f64(spec.latency / time_scale);
            links.push(
                (0..containers)
                    .map(|_| {
                        (
                            Arc::new(Link::new(spec.bandwidth * time_scale, latency)),
                            Arc::new(Link::new(spec.bandwidth * time_scale, latency)),
                        )
                    })
                    .collect(),
            );
        }
        Self { cluster, ml, links, time_scale, interposer: None, seqs: Mutex::new(BTreeMap::new()) }
    }

    /// Arm a chaos interposer: [`transmit_interposed`](Self::transmit_interposed)
    /// consults it per message. Plain [`transmit`](Self::transmit) callers
    /// (the cross-DC demo coordinator, collectives) are deliberately exempt —
    /// they assume reliable delivery.
    pub fn with_interposer(mut self, ip: Arc<dyn Interposer>) -> Self {
        self.interposer = Some(ip);
        self
    }

    pub fn has_interposer(&self) -> bool {
        self.interposer.is_some()
    }

    pub fn gpus(&self) -> usize {
        self.ml.total_gpus()
    }

    /// Block the caller for the transfer time of `bytes` from `src` to `dst`
    /// (shared-link contention included). Loopback returns immediately.
    pub fn transmit(&self, src: usize, dst: usize, bytes: usize) {
        let Some(level) = self.cluster.bottleneck_level(src, dst) else {
            return;
        };
        let e = &self.links[level][self.ml.worker_of(src, level)].0;
        let i = &self.links[level][self.ml.worker_of(dst, level)].1;
        Link::transmit_multi(&[e, i], bytes);
    }

    /// [`transmit`](Self::transmit) under the armed [`Interposer`]: pays
    /// pacing either way (the bytes leave the NIC), returns whether the
    /// message survived the network. `true` means the caller should hand
    /// the message to the receiver; `false` means it was eaten in flight.
    /// Loopback is exempt (always delivered, no sequence number drawn), and
    /// with no interposer armed this is exactly `transmit` + `true`.
    pub fn transmit_interposed(&self, src: usize, dst: usize, bytes: usize) -> bool {
        if src == dst {
            return true;
        }
        let Some(ip) = &self.interposer else {
            self.transmit(src, dst, bytes);
            return true;
        };
        let seq = {
            let mut seqs = self.seqs.lock().unwrap();
            let c = seqs.entry((src, dst)).or_insert(0);
            let s = *c;
            *c += 1;
            s
        };
        match ip.verdict(src, dst, bytes, seq) {
            Verdict::Deliver => {
                self.transmit(src, dst, bytes);
                true
            }
            Verdict::Drop => {
                self.transmit(src, dst, bytes);
                false
            }
            Verdict::Delay(sim_secs) => {
                self.transmit(src, dst, bytes);
                std::thread::sleep(Duration::from_secs_f64(
                    sim_secs.max(0.0) / self.time_scale,
                ));
                true
            }
        }
    }

    /// Wall-clock seconds → simulated seconds (undo `time_scale`).
    pub fn to_sim_time(&self, wall: f64) -> f64 {
        wall * self.time_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use std::time::Instant;

    #[test]
    fn cross_dc_slower_than_intra() {
        let f = Fabric::new(presets::dcs_x_gpus(2, 2, 10.0, 1280.0), 10.0);
        let bytes = 40_000_000; // 3.2 ms inter vs 0.025 ms intra at scale 10
        let t0 = Instant::now();
        f.transmit(0, 1, bytes); // intra
        let intra = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        f.transmit(0, 2, bytes); // inter
        let inter = t1.elapsed().as_secs_f64();
        assert!(inter > 4.0 * intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn loopback_free() {
        let f = Fabric::new(presets::cluster_s(), 1.0);
        let t0 = Instant::now();
        f.transmit(3, 3, 100_000_000);
        assert!(t0.elapsed().as_secs_f64() < 0.01);
    }

    /// A scripted interposer: drops every third message on each pair.
    struct EveryThird;
    impl Interposer for EveryThird {
        fn verdict(&self, _s: usize, _d: usize, _b: usize, seq: u64) -> Verdict {
            if seq % 3 == 2 {
                Verdict::Drop
            } else {
                Verdict::Deliver
            }
        }
    }

    #[test]
    fn interposer_rules_per_pair_in_sequence_order() {
        let f = Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0)
            .with_interposer(Arc::new(EveryThird));
        assert!(f.has_interposer());
        let got: Vec<bool> = (0..6).map(|_| f.transmit_interposed(0, 1, 8)).collect();
        assert_eq!(got, vec![true, true, false, true, true, false]);
        // each direction draws its own sequence counter
        let rev: Vec<bool> = (0..3).map(|_| f.transmit_interposed(1, 0, 8)).collect();
        assert_eq!(rev, vec![true, true, false]);
        // loopback is exempt and draws no sequence number
        assert!(f.transmit_interposed(2, 2, 8));
        assert!(f.transmit_interposed(0, 1, 8), "seq 6 delivers");
    }

    #[test]
    fn unarmed_fabric_delivers_everything() {
        let f = Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0);
        assert!(!f.has_interposer());
        assert!((0..10).all(|_| f.transmit_interposed(0, 1, 8)));
    }

    #[test]
    fn delay_verdict_stretches_delivery() {
        struct SlowBy(f64);
        impl Interposer for SlowBy {
            fn verdict(&self, _s: usize, _d: usize, _b: usize, _q: u64) -> Verdict {
                Verdict::Delay(self.0)
            }
        }
        // 2 sim-seconds at time_scale 100 = 20 ms of wall delay
        let f = Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0)
            .with_interposer(Arc::new(SlowBy(2.0)));
        let t0 = Instant::now();
        assert!(f.transmit_interposed(0, 1, 8));
        assert!(t0.elapsed().as_secs_f64() >= 0.018, "delay verdict not applied");
    }

    #[test]
    fn time_scale_speeds_up() {
        let slow = Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 1.0);
        let fast = Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 50.0);
        let bytes = 2_000_000; // 1.6 ms at 10 Gbps
        let t0 = Instant::now();
        slow.transmit(0, 1, bytes);
        let t_slow = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        fast.transmit(0, 1, bytes);
        let t_fast = t1.elapsed().as_secs_f64();
        assert!(t_slow > 3.0 * t_fast, "scale 50 should be much faster: {t_slow} vs {t_fast}");
    }
}
