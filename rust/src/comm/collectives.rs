//! Collectives over the in-process cluster: A2A, AG, All-Reduce.
//!
//! These are the real-bytes counterparts of the patterns the stream model
//! reasons about (Eq. 3/4): `all_to_all` sends per-peer chunks, `all_gather`
//! collects a payload from a peer set, `all_reduce_f32` ring-reduces a
//! buffer. Used by the Fig. 11 latency-verification bench and the
//! cross-DC demo.

use crate::comm::cluster::WorkerCtx;

/// Exchange per-destination chunks with every other worker (A2A, Eq. 3).
/// `chunks[j]` is sent to worker `j` (`chunks[self]` is kept local).
/// Returns the received chunks indexed by source.
pub fn all_to_all(ctx: &mut WorkerCtx, tag: u32, mut chunks: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
    let n = ctx.n_workers();
    assert_eq!(chunks.len(), n, "need one chunk per worker");
    let me = ctx.id;
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); n];
    // stagger destinations to avoid all-senders-hit-one-receiver bursts;
    // chunks are moved, not cloned (§Perf: halves memcpy on the send path)
    for step in 1..n {
        let dst = (me + step) % n;
        ctx.send(dst, tag, std::mem::take(&mut chunks[dst]));
    }
    out[me] = std::mem::take(&mut chunks[me]);
    for m in ctx.recv_n(tag, n - 1) {
        out[m.from] = m.bytes;
    }
    out
}

/// Gather `payload` from each worker in `peers` (AG, Eq. 4): everyone sends
/// its payload to all peers in the set; returns (src, payload) pairs.
pub fn all_gather(
    ctx: &mut WorkerCtx,
    tag: u32,
    peers: &[usize],
    payload: &[u8],
) -> Vec<(usize, Vec<u8>)> {
    let me = ctx.id;
    for &p in peers {
        if p != me {
            ctx.send(p, tag, payload.to_vec());
        }
    }
    let expect = peers.iter().filter(|&&p| p != me).count();
    ctx.recv_n(tag, expect).into_iter().map(|m| (m.from, m.bytes)).collect()
}

/// Ring All-Reduce (sum) of an f32 buffer across all workers.
pub fn all_reduce_f32(ctx: &mut WorkerCtx, tag: u32, buf: &mut [f32]) {
    let n = ctx.n_workers();
    if n == 1 {
        return;
    }
    let me = ctx.id;
    let next = (me + 1) % n;
    // reduce-scatter + all-gather ring, chunked by rank
    let chunks: Vec<std::ops::Range<usize>> = (0..n)
        .map(|i| {
            let per = buf.len().div_ceil(n);
            (i * per).min(buf.len())..((i + 1) * per).min(buf.len())
        })
        .collect();
    // reduce-scatter
    for step in 0..n - 1 {
        let send_idx = (me + n - step) % n;
        let bytes = f32s_to_bytes(&buf[chunks[send_idx].clone()]);
        ctx.send(next, tag, bytes);
        let m = ctx.recv(tag);
        let recv_idx = (me + n - step - 1) % n;
        let vals = bytes_to_f32s(&m.bytes);
        for (b, v) in buf[chunks[recv_idx].clone()].iter_mut().zip(vals) {
            *b += v;
        }
    }
    // all-gather
    for step in 0..n - 1 {
        let send_idx = (me + 1 + n - step) % n;
        let bytes = f32s_to_bytes(&buf[chunks[send_idx].clone()]);
        ctx.send(next, tag + 1, bytes);
        let m = ctx.recv(tag + 1);
        let recv_idx = (me + n - step) % n;
        let vals = bytes_to_f32s(&m.bytes);
        buf[chunks[recv_idx].clone()].copy_from_slice(&vals);
    }
}

pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    assert!(b.len() % 4 == 0);
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::cluster::run_workers;
    use crate::comm::fabric::Fabric;
    use std::sync::Arc;

    fn fast_fabric(gpus: usize) -> Arc<Fabric> {
        Arc::new(Fabric::new(presets::dcs_x_gpus(2, gpus / 2, 1000.0, 8000.0), 1000.0))
    }

    /// Satellite: a collective with one straggling participant completes
    /// (nobody times out or deadlocks waiting) and still reduces correctly —
    /// everyone is simply gated on the slowest member, which is the
    /// bulk-synchronous behaviour the chaos harness's slow-node stalls lean
    /// on.
    #[test]
    fn all_reduce_completes_and_is_correct_with_a_straggler() {
        use std::time::{Duration, Instant};
        let f = fast_fabric(4);
        let stall = Duration::from_millis(80);
        let out = run_workers(f, move |mut ctx| {
            if ctx.id == 2 {
                std::thread::sleep(stall); // the straggler joins late
            }
            let t0 = Instant::now();
            let mut buf = vec![ctx.id as f32 + 1.0; 8];
            all_reduce_f32(&mut ctx, 11, &mut buf);
            (buf, t0.elapsed())
        });
        for (id, (buf, _)) in out.iter().enumerate() {
            assert!(buf.iter().all(|&v| v == 10.0), "worker {id}: {buf:?}");
        }
        // non-stragglers are gated on the straggler's arrival: their
        // collective wall time absorbs (most of) the stall
        let fastest = out.iter().enumerate().filter(|(id, _)| *id != 2);
        for (id, (_, dt)) in fastest {
            assert!(
                *dt >= Duration::from_millis(40),
                "worker {id} finished in {dt:?} — cannot precede the straggler"
            );
        }
    }

    #[test]
    fn a2a_delivers_correct_chunks() {
        let f = fast_fabric(4);
        let out = run_workers(f, |mut ctx| {
            let me = ctx.id as u8;
            let chunks: Vec<Vec<u8>> =
                (0..4).map(|dst| vec![me, dst as u8]).collect();
            all_to_all(&mut ctx, 10, chunks)
        });
        for (me, rows) in out.iter().enumerate() {
            for (src, chunk) in rows.iter().enumerate() {
                assert_eq!(chunk, &vec![src as u8, me as u8], "worker {me} from {src}");
            }
        }
    }

    #[test]
    fn ag_gathers_peer_set_only() {
        let f = fast_fabric(4);
        let out = run_workers(f, |mut ctx| {
            // two domains: {0,1} and {2,3}
            let peers: Vec<usize> =
                if ctx.id < 2 { vec![0, 1] } else { vec![2, 3] };
            let me = ctx.id as u8;
            let mut got = all_gather(&mut ctx, 20, &peers, &[me]);
            got.sort();
            got
        });
        assert_eq!(out[0], vec![(1, vec![1u8])]);
        assert_eq!(out[3], vec![(2, vec![2u8])]);
    }

    #[test]
    fn all_reduce_sums() {
        let f = fast_fabric(4);
        let out = run_workers(f, |mut ctx| {
            let mut buf: Vec<f32> = (0..10).map(|i| (ctx.id * 10 + i) as f32).collect();
            all_reduce_f32(&mut ctx, 30, &mut buf);
            buf
        });
        // sum over workers of (id*10 + i) = 60 + 4i
        for rank in &out {
            for (i, v) in rank.iter().enumerate() {
                assert_eq!(*v, 60.0 + 4.0 * i as f32, "index {i}");
            }
        }
    }

    #[test]
    fn all_reduce_uneven_lengths() {
        let f = fast_fabric(4);
        let out = run_workers(f, |mut ctx| {
            let mut buf = vec![1.0f32; 7]; // not divisible by 4
            all_reduce_f32(&mut ctx, 40, &mut buf);
            buf
        });
        for rank in &out {
            assert!(rank.iter().all(|&v| v == 4.0), "{rank:?}");
        }
    }
}
