//! In-process worker cluster: N OS threads ("GPUs") exchanging real payloads
//! through the throttled [`Fabric`](super::fabric::Fabric).
//!
//! This is the runnable substitute for the paper's NCCL testbed: every byte
//! of dispatch data and (compressed) expert weights actually crosses a
//! rate-limited link, so measured iteration times reproduce the paper's
//! bandwidth-ratio effects (DESIGN.md §Substitutions).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use crate::comm::fabric::Fabric;

/// A message between workers. `tag` disambiguates phases/collectives.
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub tag: u32,
    pub bytes: Vec<u8>,
}

/// Per-worker context handed to the worker body.
pub struct WorkerCtx {
    pub id: usize,
    pub fabric: Arc<Fabric>,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    stash: Vec<Message>,
    barrier: Arc<Barrier>,
}

impl WorkerCtx {
    /// Synchronous send: blocks for the transfer time, then delivers.
    pub fn send(&self, to: usize, tag: u32, bytes: Vec<u8>) {
        self.fabric.transmit(self.id, to, bytes.len());
        // receiver may have exited only at teardown; ignore then
        let _ = self.senders[to].send(Message { from: self.id, tag, bytes });
    }

    /// Hand out an independent sender handle + fabric for async use
    /// (the asynchronous communicator owns one).
    pub fn endpoints(&self) -> (usize, Arc<Fabric>, Vec<Sender<Message>>) {
        (self.id, self.fabric.clone(), self.senders.clone())
    }

    /// Receive the next message matching `tag` (stashing others).
    pub fn recv(&mut self, tag: u32) -> Message {
        if let Some(pos) = self.stash.iter().position(|m| m.tag == tag) {
            return self.stash.swap_remove(pos);
        }
        loop {
            let m = self.inbox.recv().expect("cluster torn down while receiving");
            if m.tag == tag {
                return m;
            }
            self.stash.push(m);
        }
    }

    /// Receive exactly `n` messages with `tag`.
    pub fn recv_n(&mut self, tag: u32, n: usize) -> Vec<Message> {
        (0..n).map(|_| self.recv(tag)).collect()
    }

    /// Full-cluster barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }
}

/// Spawn one worker thread per GPU and run `body` to completion on each.
/// Returns the per-worker results in id order.
pub fn run_workers<T, F>(fabric: Arc<Fabric>, body: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(WorkerCtx) -> T + Send + Sync + 'static,
{
    let n = fabric.gpus();
    let (senders, inboxes): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Message>()).unzip();
    let barrier = Arc::new(Barrier::new(n));
    let body = Arc::new(body);
    let mut handles = Vec::with_capacity(n);
    for (id, inbox) in inboxes.into_iter().enumerate() {
        let ctx = WorkerCtx {
            id,
            fabric: fabric.clone(),
            senders: senders.clone(),
            inbox,
            stash: Vec::new(),
            barrier: barrier.clone(),
        };
        let body = body.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{id}"))
                .spawn(move || body(ctx))
                .expect("spawn worker"),
        );
    }
    drop(senders);
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    fn small_fabric() -> Arc<Fabric> {
        Arc::new(Fabric::new(presets::dcs_x_gpus(2, 2, 100.0, 1000.0), 100.0))
    }

    #[test]
    fn ring_message_passing() {
        let f = small_fabric();
        let out = run_workers(f, |mut ctx| {
            let n = ctx.n_workers();
            let next = (ctx.id + 1) % n;
            ctx.send(next, 1, vec![ctx.id as u8]);
            let m = ctx.recv(1);
            (m.from, m.bytes[0])
        });
        for (id, (from, payload)) in out.iter().enumerate() {
            let want = (id + 4 - 1) % 4;
            assert_eq!(*from, want);
            assert_eq!(*payload as usize, want);
        }
    }

    #[test]
    fn tag_stashing_handles_out_of_order() {
        let f = small_fabric();
        let out = run_workers(f, |mut ctx| {
            if ctx.id == 0 {
                // send tag 2 first, then tag 1
                ctx.send(1, 2, vec![2]);
                ctx.send(1, 1, vec![1]);
                0
            } else if ctx.id == 1 {
                let a = ctx.recv(1); // must stash the tag-2 message
                let b = ctx.recv(2);
                (a.bytes[0] + b.bytes[0]) as usize
            } else {
                0
            }
        });
        assert_eq!(out[1], 3);
    }

    #[test]
    fn barrier_synchronizes() {
        let f = small_fabric();
        let out = run_workers(f, |ctx| {
            if ctx.id == 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            ctx.barrier();
            std::time::Instant::now()
        });
        let spread = out
            .iter()
            .map(|t| t.elapsed().as_secs_f64())
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), x| (lo.min(x), hi.max(x)));
        assert!(spread.1 - spread.0 < 0.02, "barrier spread too large");
    }
}
