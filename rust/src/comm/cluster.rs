//! In-process worker cluster: N OS threads ("GPUs") exchanging real payloads
//! through the throttled [`Fabric`](super::fabric::Fabric).
//!
//! This is the runnable substitute for the paper's NCCL testbed: every byte
//! of dispatch data and (compressed) expert weights actually crosses a
//! rate-limited link, so measured iteration times reproduce the paper's
//! bandwidth-ratio effects (DESIGN.md §Substitutions).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::comm::fabric::Fabric;

/// A message between workers. `tag` disambiguates phases/collectives.
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub tag: u32,
    pub bytes: Vec<u8>,
}

/// Per-worker context handed to the worker body.
pub struct WorkerCtx {
    pub id: usize,
    pub fabric: Arc<Fabric>,
    senders: Vec<Sender<Message>>,
    inbox: Receiver<Message>,
    stash: Vec<Message>,
    barrier: Arc<Barrier>,
}

impl WorkerCtx {
    /// Synchronous send: blocks for the transfer time, then delivers.
    /// Routed through the fabric's chaos interposer when one is armed.
    pub fn send(&self, to: usize, tag: u32, bytes: Vec<u8>) {
        self.send_tracked(to, tag, bytes);
    }

    /// [`send`](Self::send) that reports whether the message survived the
    /// (possibly chaos-interposed) network. Without an interposer this is
    /// always `true`.
    pub fn send_tracked(&self, to: usize, tag: u32, bytes: Vec<u8>) -> bool {
        if !self.fabric.transmit_interposed(self.id, to, bytes.len()) {
            return false;
        }
        // receiver may have exited only at teardown; ignore then
        let _ = self.senders[to].send(Message { from: self.id, tag, bytes });
        true
    }

    /// Hand out an independent sender handle + fabric for async use
    /// (the asynchronous communicator owns one).
    pub fn endpoints(&self) -> (usize, Arc<Fabric>, Vec<Sender<Message>>) {
        (self.id, self.fabric.clone(), self.senders.clone())
    }

    /// Receive the next message matching `tag` (stashing others).
    pub fn recv(&mut self, tag: u32) -> Message {
        if let Some(pos) = self.stash.iter().position(|m| m.tag == tag) {
            return self.stash.swap_remove(pos);
        }
        loop {
            let m = self.inbox.recv().expect("cluster torn down while receiving");
            if m.tag == tag {
                return m;
            }
            self.stash.push(m);
        }
    }

    /// [`recv`](Self::recv) with a deadline: `None` on timeout (or teardown),
    /// stashing non-matching arrivals either way. This is the wedge-free
    /// receive the chaos harness builds on — a dead peer costs a timeout,
    /// never a hang.
    pub fn recv_timeout(&mut self, tag: u32, timeout: Duration) -> Option<Message> {
        if let Some(pos) = self.stash.iter().position(|m| m.tag == tag) {
            return Some(self.stash.swap_remove(pos));
        }
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            match self.inbox.recv_timeout(deadline - now) {
                Ok(m) if m.tag == tag => return Some(m),
                Ok(m) => self.stash.push(m),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                    return None;
                }
            }
        }
    }

    /// Receive exactly `n` messages with `tag`.
    pub fn recv_n(&mut self, tag: u32, n: usize) -> Vec<Message> {
        (0..n).map(|_| self.recv(tag)).collect()
    }

    /// Full-cluster barrier.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }
}

/// Spawn one worker thread per GPU and run `body` to completion on each.
/// Returns the per-worker results in id order.
pub fn run_workers<T, F>(fabric: Arc<Fabric>, body: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(WorkerCtx) -> T + Send + Sync + 'static,
{
    let n = fabric.gpus();
    let (senders, inboxes): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Message>()).unzip();
    let barrier = Arc::new(Barrier::new(n));
    let body = Arc::new(body);
    let mut handles = Vec::with_capacity(n);
    for (id, inbox) in inboxes.into_iter().enumerate() {
        let ctx = WorkerCtx {
            id,
            fabric: fabric.clone(),
            senders: senders.clone(),
            inbox,
            stash: Vec::new(),
            barrier: barrier.clone(),
        };
        let body = body.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("worker-{id}"))
                .spawn(move || body(ctx))
                .expect("spawn worker"),
        );
    }
    drop(senders);
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    fn small_fabric() -> Arc<Fabric> {
        Arc::new(Fabric::new(presets::dcs_x_gpus(2, 2, 100.0, 1000.0), 100.0))
    }

    #[test]
    fn ring_message_passing() {
        let f = small_fabric();
        let out = run_workers(f, |mut ctx| {
            let n = ctx.n_workers();
            let next = (ctx.id + 1) % n;
            ctx.send(next, 1, vec![ctx.id as u8]);
            let m = ctx.recv(1);
            (m.from, m.bytes[0])
        });
        for (id, (from, payload)) in out.iter().enumerate() {
            let want = (id + 4 - 1) % 4;
            assert_eq!(*from, want);
            assert_eq!(*payload as usize, want);
        }
    }

    #[test]
    fn tag_stashing_handles_out_of_order() {
        let f = small_fabric();
        let out = run_workers(f, |mut ctx| {
            if ctx.id == 0 {
                // send tag 2 first, then tag 1
                ctx.send(1, 2, vec![2]);
                ctx.send(1, 1, vec![1]);
                0
            } else if ctx.id == 1 {
                let a = ctx.recv(1); // must stash the tag-2 message
                let b = ctx.recv(2);
                (a.bytes[0] + b.bytes[0]) as usize
            } else {
                0
            }
        });
        assert_eq!(out[1], 3);
    }

    /// Satellite: delivery order per channel pair is the sender's program
    /// order, even with a chaos interposer delaying and dropping messages
    /// in flight (the interposer acts inline on the sender, so surviving
    /// messages of one pair can never overtake each other).
    #[test]
    fn per_pair_delivery_preserves_send_order_under_chaos() {
        use crate::comm::fabric::{Interposer, Verdict};
        struct Jitter;
        impl Interposer for Jitter {
            fn verdict(&self, _s: usize, _d: usize, _b: usize, seq: u64) -> Verdict {
                match seq % 3 {
                    0 => Verdict::Delay(0.2), // 2 ms of wall delay at scale 100
                    1 => Verdict::Drop,
                    _ => Verdict::Deliver,
                }
            }
        }
        let f = Arc::new(
            Fabric::new(presets::dcs_x_gpus(2, 2, 100.0, 1000.0), 100.0)
                .with_interposer(Arc::new(Jitter)),
        );
        let out = run_workers(f, |mut ctx| {
            if ctx.id == 0 {
                let delivered: Vec<u8> = (0..12u8)
                    .filter(|&i| ctx.send_tracked(1, 5, vec![i]))
                    .collect();
                assert_eq!(delivered.len(), 8, "seq % 3 == 1 must be eaten");
                // tell the receiver how many survived (reliable tag-9 note:
                // retry until the interposer lets one through)
                while !ctx.send_tracked(1, 9, vec![delivered.len() as u8]) {}
                delivered
            } else if ctx.id == 1 {
                let n = ctx.recv(9).bytes[0] as usize;
                ctx.recv_n(5, n).into_iter().map(|m| m.bytes[0]).collect()
            } else {
                vec![]
            }
        });
        // the receiver sees exactly the survivors, in send order
        let mut got = out[1].clone();
        assert_eq!(got.len(), 8);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "per-pair order violated: {got:?}");
        got.dedup();
        assert_eq!(got.len(), 8, "duplicate delivery");
    }

    #[test]
    fn recv_timeout_expires_instead_of_wedging() {
        let f = small_fabric();
        let out = run_workers(f, |mut ctx| {
            if ctx.id == 0 {
                // nobody ever sends tag 42: the receive must expire
                let t0 = Instant::now();
                let got = ctx.recv_timeout(42, Duration::from_millis(30));
                assert!(got.is_none());
                assert!(t0.elapsed() >= Duration::from_millis(25), "returned too early");
                // non-matching arrivals are stashed, not lost
                let m = ctx.recv_timeout(7, Duration::from_millis(500)).expect("tag 7");
                assert_eq!(m.bytes, vec![1]);
                let stashed = ctx.recv_timeout(8, Duration::from_millis(500)).expect("tag 8");
                stashed.bytes[0]
            } else if ctx.id == 1 {
                ctx.send(0, 8, vec![9]); // out-of-order tag first
                ctx.send(0, 7, vec![1]);
                0
            } else {
                0
            }
        });
        assert_eq!(out[0], 9);
    }

    #[test]
    fn barrier_synchronizes() {
        let f = small_fabric();
        let out = run_workers(f, |ctx| {
            if ctx.id == 0 {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            ctx.barrier();
            std::time::Instant::now()
        });
        let spread = out
            .iter()
            .map(|t| t.elapsed().as_secs_f64())
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), x| (lo.min(x), hi.max(x)));
        assert!(spread.1 - spread.0 < 0.02, "barrier spread too large");
    }
}
