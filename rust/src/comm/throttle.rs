//! Bandwidth-throttled links: real bytes, real wall-clock pacing.
//!
//! A [`Link`] models one serializing interconnect (a DC uplink, a node's
//! PCIe switch port): transfers reserve FIFO time slots sized
//! `bytes / bandwidth` and the sender sleeps until the slot ends (+ one-way
//! latency). Concurrent senders therefore share the link serially, which is
//! the paper's 10 Gbps-Ethernet bottleneck behaviour at in-process scale.

use std::sync::Mutex;
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Link {
    bytes_per_sec: f64,
    latency: Duration,
    busy_until: Mutex<Option<Instant>>,
}

impl Link {
    pub fn new(bytes_per_sec: f64, latency: Duration) -> Self {
        assert!(bytes_per_sec > 0.0);
        Self { bytes_per_sec, latency, busy_until: Mutex::new(None) }
    }

    pub fn bandwidth(&self) -> f64 {
        self.bytes_per_sec
    }

    /// Reserve a FIFO slot for `bytes`; returns the slot end (excl. latency).
    pub fn reserve(&self, bytes: usize) -> Instant {
        let dur = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let now = Instant::now();
        let mut busy = self.busy_until.lock().unwrap();
        let start = busy.map_or(now, |b| b.max(now));
        let end = start + dur;
        *busy = Some(end);
        end
    }

    /// Reserve and block until delivery time (slot end + latency).
    pub fn transmit(&self, bytes: usize) {
        let end = self.reserve(bytes) + self.latency;
        sleep_until(end);
    }

    /// Delivery time for a transfer that must traverse several links
    /// (reserves all, returns the latest end + max latency).
    pub fn transmit_multi(links: &[&Link], bytes: usize) {
        let mut end = Instant::now();
        let mut lat = Duration::ZERO;
        for l in links {
            end = end.max(l.reserve(bytes));
            lat = lat.max(l.latency);
        }
        sleep_until(end + lat);
    }
}

pub fn sleep_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_takes_bytes_over_bandwidth() {
        let link = Link::new(1e8, Duration::ZERO); // 100 MB/s
        let t0 = Instant::now();
        link.transmit(5_000_000); // 50 ms
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.045..0.2).contains(&dt), "took {dt}s");
    }

    #[test]
    fn concurrent_senders_serialize() {
        use std::sync::Arc;
        let link = Arc::new(Link::new(1e8, Duration::ZERO));
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let l = link.clone();
                std::thread::spawn(move || l.transmit(2_500_000)) // 25 ms each
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.09, "4 × 25 ms must serialize, took {dt}s");
    }

    #[test]
    fn latency_added() {
        let link = Link::new(1e12, Duration::from_millis(30));
        let t0 = Instant::now();
        link.transmit(8);
        assert!(t0.elapsed().as_secs_f64() >= 0.028);
    }

    #[test]
    fn sleep_until_past_deadline_returns_immediately() {
        // the timeout path: an already-expired slot end must not sleep
        let t0 = Instant::now();
        sleep_until(t0 - Duration::from_millis(10));
        assert!(t0.elapsed().as_secs_f64() < 0.05, "slept on an expired deadline");
    }

    #[test]
    fn reserve_queues_fifo_slots_under_back_pressure() {
        let link = Link::new(1e8, Duration::ZERO); // 100 MB/s
        let first = link.reserve(1_000_000); // 10 ms slot
        let second = link.reserve(1_000_000);
        let gap = second.duration_since(first).as_secs_f64();
        assert!(gap >= 0.009, "second slot must queue behind the first, gap {gap}s");
    }

    #[test]
    fn multi_link_takes_slowest() {
        let fast = Link::new(1e9, Duration::ZERO);
        let slow = Link::new(1e8, Duration::ZERO);
        let t0 = Instant::now();
        Link::transmit_multi(&[&fast, &slow], 5_000_000); // 50 ms on slow
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.045, "took {dt}s");
    }
}
