//! Real-bytes communication runtime: bandwidth-throttled links ([`throttle`]),
//! the hierarchical [`fabric`], the in-process worker [`cluster`], collective
//! operations ([`collectives`]) and the paper's asynchronous communicator
//! ([`async_comm`], §IV-B Fig. 10).
//!
//! Unlike [`netsim`](crate::netsim) (fluid simulation for large scales), this
//! module moves actual payload bytes through rate-limited channels so the
//! cross-DC demo and the Fig. 11/12/15 benches measure genuine wall-clock
//! behaviour, including overlap and contention.

pub mod async_comm;
pub mod cluster;
pub mod collectives;
pub mod fabric;
pub mod throttle;

pub use async_comm::{AsyncCommunicator, Outbound};
pub use cluster::{run_workers, Message, WorkerCtx};
pub use fabric::Fabric;
pub use throttle::Link;
