//! The asynchronous communicator (HybridEP §IV-B, Fig. 10).
//!
//! Two stages:
//!
//! 1. **Initialization** — each MoE layer's (SREncoded) experts are pushed
//!    into the *Send Queue*; this is fused with the previous optimizer step.
//! 2. **Asyn-comm** — a dedicated communicator thread pops the queue and
//!    performs the AG transfers *while the main thread runs pre-expert
//!    computation*; results land in the peers' inboxes (*Recv Queue*) and
//!    are SRDecoded right before expert compute.
//!
//! The communicator owns independent channel endpoints, so the worker thread
//! never blocks on migration traffic — that is exactly the overlap the
//! stream model's Eq. 7 `min(Lat^PE, Lat^AG)` term claims.
//!
//! Hand-offs to a peer inbox are retried with bounded exponential backoff
//! ([`RetryCfg`]) before the message is counted as dropped: a briefly wedged
//! receiver loses nothing, while a peer that stays gone degrades to a
//! counted drop instead of wedging the communicator (the persistent-failure
//! half of degraded mode lives in `netsim::detect` / `plan::replica`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::comm::cluster::Message;
use crate::comm::fabric::Fabric;

/// Bounded-retry policy for transient send failures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryCfg {
    /// Total tries including the first (>= 1).
    pub max_attempts: usize,
    /// Backoff before the first retry; doubles on each subsequent retry.
    pub base_backoff: Duration,
    /// Ceiling on any single backoff sleep.
    pub max_backoff: Duration,
}

impl Default for RetryCfg {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(100),
        }
    }
}

impl RetryCfg {
    /// Backoff before retry `attempt` (1-based): `base * 2^(attempt - 1)`,
    /// saturating exactly at [`max_backoff`](Self::max_backoff).
    ///
    /// Doubling is checked, so large attempt counts can neither overflow
    /// the `Duration` math nor plateau below the cap (the previous
    /// hard-coded `2^16` exponent clamp pinned a 1 ns base at ~65 µs even
    /// with a 100 ms ceiling). A zero base stays zero.
    pub fn backoff(&self, attempt: u32) -> Duration {
        if self.base_backoff.is_zero() || self.base_backoff >= self.max_backoff {
            return if self.base_backoff.is_zero() { Duration::ZERO } else { self.max_backoff };
        }
        let mut b = self.base_backoff;
        // at most ~127 doublings fit below any max_backoff, so this loop is
        // short regardless of the attempt count
        for _ in 1..attempt {
            match b.checked_mul(2) {
                Some(d) if d < self.max_backoff => b = d,
                _ => return self.max_backoff,
            }
        }
        b
    }
}

/// Run `op` under `cfg`: return the first `Ok`, sleeping the exponential
/// backoff between tries, or the last `Err` once attempts are exhausted.
pub fn with_retry<T, E>(cfg: &RetryCfg, mut op: impl FnMut() -> Result<T, E>) -> Result<T, E> {
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                attempt += 1;
                if attempt as usize >= cfg.max_attempts.max(1) {
                    return Err(e);
                }
                std::thread::sleep(cfg.backoff(attempt));
            }
        }
    }
}

/// One queued outbound migration.
#[derive(Debug)]
pub struct Outbound {
    pub to: usize,
    pub tag: u32,
    pub bytes: Vec<u8>,
}

pub struct AsyncCommunicator {
    send_q: Option<Sender<Outbound>>,
    worker: Option<JoinHandle<usize>>,
}

impl AsyncCommunicator {
    /// Start the communicator thread for worker `id` with the default
    /// transient-failure retry policy.
    pub fn start(id: usize, fabric: Arc<Fabric>, peers: Vec<Sender<Message>>) -> Self {
        Self::start_with_retry(id, fabric, peers, RetryCfg::default())
    }

    /// Start with an explicit transient-failure retry policy.
    pub fn start_with_retry(
        id: usize,
        fabric: Arc<Fabric>,
        peers: Vec<Sender<Message>>,
        retry: RetryCfg,
    ) -> Self {
        let (tx, rx): (Sender<Outbound>, Receiver<Outbound>) = channel();
        let worker = std::thread::Builder::new()
            .name(format!("asyncomm-{id}"))
            .spawn(move || {
                let mut sent = 0usize;
                while let Ok(out) = rx.recv() {
                    let Outbound { to, tag, bytes } = out;
                    // pacing happens here, off the compute thread
                    fabric.transmit(id, to, bytes.len());
                    // the hand-off is retried with backoff; a peer that
                    // stays gone past max_attempts drops the message, which
                    // keeps it out of the delivered count below
                    let mut pending = Some(Message { from: id, tag, bytes });
                    let delivered = with_retry(&retry, || {
                        match peers[to].send(pending.take().expect("pending message")) {
                            Ok(()) => Ok(()),
                            Err(back) => {
                                pending = Some(back.0);
                                Err(())
                            }
                        }
                    });
                    sent += usize::from(delivered.is_ok());
                }
                sent
            })
            .expect("spawn async communicator");
        Self { send_q: Some(tx), worker: Some(worker) }
    }

    /// Enqueue a migration (returns immediately — Send Queue semantics).
    pub fn enqueue(&self, out: Outbound) {
        self.send_q.as_ref().expect("communicator closed").send(out).expect("comm thread died");
    }

    /// Close the queue and wait for all pending transfers; returns the
    /// number of messages actually sent.
    pub fn finish(mut self) -> usize {
        drop(self.send_q.take());
        self.worker.take().expect("already finished").join().expect("comm thread panicked")
    }
}

impl Drop for AsyncCommunicator {
    fn drop(&mut self) {
        drop(self.send_q.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::cluster::run_workers;
    use std::time::Instant;

    #[test]
    fn overlaps_compute_with_transfers() {
        // 2 workers; worker 0 enqueues a slow transfer then "computes";
        // total ≈ max(compute, transfer), not sum.
        let fabric = Arc::new(Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 10.0));
        let out = run_workers(fabric, |mut ctx| {
            if ctx.id == 0 {
                let (id, fabric, peers) = ctx.endpoints();
                let comm = AsyncCommunicator::start(id, fabric, peers);
                let t0 = Instant::now();
                // ~80 ms on the scaled 10 Gbps link
                comm.enqueue(Outbound { to: 1, tag: 7, bytes: vec![0u8; 1_000_000] });
                // "pre-expert compute" on the main thread: 60 ms
                std::thread::sleep(std::time::Duration::from_millis(60));
                let sent = comm.finish();
                assert_eq!(sent, 1);
                t0.elapsed().as_secs_f64()
            } else {
                let m = ctx.recv(7);
                assert_eq!(m.bytes.len(), 1_000_000);
                0.0
            }
        });
        let total = out[0];
        assert!(total < 0.125, "no overlap: took {total}s (expected ~max(0.06, 0.08))");
    }

    #[test]
    fn preserves_fifo_order_per_destination() {
        let fabric = Arc::new(Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0));
        let out = run_workers(fabric, |mut ctx| {
            if ctx.id == 0 {
                let (id, fabric, peers) = ctx.endpoints();
                let comm = AsyncCommunicator::start(id, fabric, peers);
                for i in 0..10u8 {
                    comm.enqueue(Outbound { to: 1, tag: 3, bytes: vec![i] });
                }
                comm.finish();
                vec![]
            } else {
                ctx.recv_n(3, 10).into_iter().map(|m| m.bytes[0]).collect::<Vec<_>>()
            }
        });
        assert_eq!(out[1], (0..10).collect::<Vec<u8>>());
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let cfg = RetryCfg {
            max_attempts: 8,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(8),
        };
        let ms: Vec<u128> = (1..=6).map(|a| cfg.backoff(a).as_millis()).collect();
        assert_eq!(ms, vec![1, 2, 4, 8, 8, 8]);
    }

    /// Regression: large attempt counts must saturate exactly at
    /// `max_backoff` — no `Duration` overflow, no sub-cap plateau. The old
    /// exponent clamp (`min(16)`) froze a 1 ns base at ~65 µs forever.
    #[test]
    fn backoff_saturates_at_max_for_large_attempts() {
        let cfg = RetryCfg {
            max_attempts: usize::MAX,
            base_backoff: Duration::from_nanos(1),
            max_backoff: Duration::from_secs(1),
        };
        for attempt in [64, 65, 100, 1_000, u32::MAX] {
            assert_eq!(cfg.backoff(attempt), cfg.max_backoff, "attempt {attempt}");
        }
        // the cap is reachable, not merely an upper bound: 2^30 ns ≈ 1.07 s
        assert_eq!(cfg.backoff(31), cfg.max_backoff);
        assert_eq!(cfg.backoff(30), Duration::from_nanos(1 << 29));
        // a base at/above the cap pins every retry to the cap
        let flat = RetryCfg {
            base_backoff: Duration::from_secs(2),
            max_backoff: Duration::from_secs(1),
            ..cfg
        };
        assert_eq!(flat.backoff(1), Duration::from_secs(1));
        assert_eq!(flat.backoff(u32::MAX), Duration::from_secs(1));
        // a zero base never sleeps, even at huge attempts (no infinite loop)
        let zero = RetryCfg { base_backoff: Duration::ZERO, ..cfg };
        assert_eq!(zero.backoff(u32::MAX), Duration::ZERO);
        // attempt 0 (pre-first-try probe) behaves like attempt 1
        assert_eq!(cfg.backoff(0), Duration::from_nanos(1));
    }

    #[test]
    fn retry_recovers_from_transient_failures() {
        let cfg = RetryCfg { base_backoff: Duration::from_micros(10), ..Default::default() };
        let mut calls = 0u32;
        let out: Result<u32, &str> = with_retry(&cfg, || {
            calls += 1;
            if calls < 3 {
                Err("transient")
            } else {
                Ok(99)
            }
        });
        assert_eq!(out, Ok(99));
        assert_eq!(calls, 3, "two transient failures then the success");
    }

    #[test]
    fn retry_gives_up_after_max_attempts() {
        let cfg = RetryCfg {
            max_attempts: 3,
            base_backoff: Duration::from_micros(10),
            max_backoff: Duration::from_micros(10),
        };
        let mut calls = 0u32;
        let out: Result<(), &str> = with_retry(&cfg, || {
            calls += 1;
            Err("permanent")
        });
        assert_eq!(out, Err("permanent"));
        assert_eq!(calls, 3, "the bound is total tries, not retries");
    }

    #[test]
    fn dropped_peer_exhausts_retries_without_wedging() {
        // peer 1's inbox receiver is gone before the send: every attempt
        // fails, the bounded retry gives up, and finish() reports zero
        // delivered instead of hanging or panicking the communicator thread
        let fabric = Arc::new(Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0));
        let (tx_live, _rx_live) = channel::<Message>();
        let (tx_dead, rx_dead) = channel::<Message>();
        drop(rx_dead);
        let cfg = RetryCfg {
            max_attempts: 3,
            base_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(1),
        };
        let comm = AsyncCommunicator::start_with_retry(0, fabric, vec![tx_live, tx_dead], cfg);
        comm.enqueue(Outbound { to: 1, tag: 9, bytes: vec![0u8; 64] });
        assert_eq!(comm.finish(), 0, "a send to a departed peer must not count as delivered");
    }
}
