//! The asynchronous communicator (HybridEP §IV-B, Fig. 10).
//!
//! Two stages:
//!
//! 1. **Initialization** — each MoE layer's (SREncoded) experts are pushed
//!    into the *Send Queue*; this is fused with the previous optimizer step.
//! 2. **Asyn-comm** — a dedicated communicator thread pops the queue and
//!    performs the AG transfers *while the main thread runs pre-expert
//!    computation*; results land in the peers' inboxes (*Recv Queue*) and
//!    are SRDecoded right before expert compute.
//!
//! The communicator owns independent channel endpoints, so the worker thread
//! never blocks on migration traffic — that is exactly the overlap the
//! stream model's Eq. 7 `min(Lat^PE, Lat^AG)` term claims.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::comm::cluster::Message;
use crate::comm::fabric::Fabric;

/// One queued outbound migration.
#[derive(Debug)]
pub struct Outbound {
    pub to: usize,
    pub tag: u32,
    pub bytes: Vec<u8>,
}

pub struct AsyncCommunicator {
    send_q: Option<Sender<Outbound>>,
    worker: Option<JoinHandle<usize>>,
}

impl AsyncCommunicator {
    /// Start the communicator thread for worker `id`.
    pub fn start(id: usize, fabric: Arc<Fabric>, peers: Vec<Sender<Message>>) -> Self {
        let (tx, rx): (Sender<Outbound>, Receiver<Outbound>) = channel();
        let worker = std::thread::Builder::new()
            .name(format!("asyncomm-{id}"))
            .spawn(move || {
                let mut sent = 0usize;
                while let Ok(out) = rx.recv() {
                    // pacing happens here, off the compute thread
                    fabric.transmit(id, out.to, out.bytes.len());
                    let _ = peers[out.to]
                        .send(Message { from: id, tag: out.tag, bytes: out.bytes });
                    sent += 1;
                }
                sent
            })
            .expect("spawn async communicator");
        Self { send_q: Some(tx), worker: Some(worker) }
    }

    /// Enqueue a migration (returns immediately — Send Queue semantics).
    pub fn enqueue(&self, out: Outbound) {
        self.send_q.as_ref().expect("communicator closed").send(out).expect("comm thread died");
    }

    /// Close the queue and wait for all pending transfers; returns the
    /// number of messages actually sent.
    pub fn finish(mut self) -> usize {
        drop(self.send_q.take());
        self.worker.take().expect("already finished").join().expect("comm thread panicked")
    }
}

impl Drop for AsyncCommunicator {
    fn drop(&mut self) {
        drop(self.send_q.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::comm::cluster::run_workers;
    use std::time::Instant;

    #[test]
    fn overlaps_compute_with_transfers() {
        // 2 workers; worker 0 enqueues a slow transfer then "computes";
        // total ≈ max(compute, transfer), not sum.
        let fabric = Arc::new(Fabric::new(presets::dcs_x_gpus(2, 1, 10.0, 128.0), 10.0));
        let out = run_workers(fabric, |mut ctx| {
            if ctx.id == 0 {
                let (id, fabric, peers) = ctx.endpoints();
                let comm = AsyncCommunicator::start(id, fabric, peers);
                let t0 = Instant::now();
                // ~80 ms on the scaled 10 Gbps link
                comm.enqueue(Outbound { to: 1, tag: 7, bytes: vec![0u8; 1_000_000] });
                // "pre-expert compute" on the main thread: 60 ms
                std::thread::sleep(std::time::Duration::from_millis(60));
                let sent = comm.finish();
                assert_eq!(sent, 1);
                t0.elapsed().as_secs_f64()
            } else {
                let m = ctx.recv(7);
                assert_eq!(m.bytes.len(), 1_000_000);
                0.0
            }
        });
        let total = out[0];
        assert!(total < 0.125, "no overlap: took {total}s (expected ~max(0.06, 0.08))");
    }

    #[test]
    fn preserves_fifo_order_per_destination() {
        let fabric = Arc::new(Fabric::new(presets::dcs_x_gpus(2, 1, 1000.0, 1000.0), 100.0));
        let out = run_workers(fabric, |mut ctx| {
            if ctx.id == 0 {
                let (id, fabric, peers) = ctx.endpoints();
                let comm = AsyncCommunicator::start(id, fabric, peers);
                for i in 0..10u8 {
                    comm.enqueue(Outbound { to: 1, tag: 3, bytes: vec![i] });
                }
                comm.finish();
                vec![]
            } else {
                ctx.recv_n(3, 10).into_iter().map(|m| m.bytes[0]).collect::<Vec<_>>()
            }
        });
        assert_eq!(out[1], (0..10).collect::<Vec<u8>>());
    }
}
