//! Gate-routing distributions: how many tokens each GPU sends to each expert.
//!
//! The stream model assumes even activation (§III); real gates skew. The
//! schedulers consume a token matrix `tokens[src_gpu][global_expert]`, which
//! we generate uniform (paper assumption), Zipf-skewed (FasterMoE's shadowing
//! case) or from an explicit matrix.

use crate::util::rng::Rng;

/// Token routing for one iteration: `tokens[src_gpu][expert]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Routing {
    pub tokens: Vec<Vec<f64>>,
}

impl Routing {
    /// Even activation: every token slot splits uniformly over all experts.
    pub fn uniform(gpus: usize, experts: usize, tokens_per_gpu: usize, k: usize) -> Self {
        let per = (tokens_per_gpu * k) as f64 / experts as f64;
        Self { tokens: vec![vec![per; experts]; gpus] }
    }

    /// Zipf-skewed activation with exponent `s` (hot experts emerge); every
    /// GPU shares the same popularity ranking, sampled once.
    pub fn zipf(gpus: usize, experts: usize, tokens_per_gpu: usize, k: usize, s: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut weights = Rng::zipf_weights(experts, s);
        // random rank→expert assignment so the hot expert isn't always #0
        let mut perm: Vec<usize> = (0..experts).collect();
        rng.shuffle(&mut perm);
        let mut w2 = vec![0.0; experts];
        for (rank, &e) in perm.iter().enumerate() {
            w2[e] = weights[rank];
        }
        weights = w2;
        let total = (tokens_per_gpu * k) as f64;
        let tokens = (0..gpus)
            .map(|_| weights.iter().map(|w| w * total).collect())
            .collect();
        Self { tokens }
    }

    pub fn gpus(&self) -> usize {
        self.tokens.len()
    }

    pub fn experts(&self) -> usize {
        self.tokens.first().map(|t| t.len()).unwrap_or(0)
    }

    /// Tokens arriving at each expert (column sums).
    pub fn per_expert_load(&self) -> Vec<f64> {
        let e = self.experts();
        let mut load = vec![0.0; e];
        for row in &self.tokens {
            for (i, t) in row.iter().enumerate() {
                load[i] += t;
            }
        }
        load
    }

    /// Tokens sent from `src` to experts hosted on GPU `dst` under a
    /// placement (expert → host GPU).
    pub fn tokens_to_gpu(&self, src: usize, dst: usize, placement: &Placement) -> f64 {
        placement.experts_on(dst).iter().map(|&e| self.tokens[src][e]).sum()
    }

    /// Total tokens leaving each GPU (row sums) — conservation checks.
    pub fn per_gpu_tokens(&self) -> Vec<f64> {
        self.tokens.iter().map(|r| r.iter().sum()).collect()
    }

    /// Worst per-GPU remote token volume under `placement`: the max over
    /// GPUs of remote tokens *sent* or *received*. Uniform routing gives
    /// `total · (G−1)/G`; skew concentrating load on one host drives the
    /// received side toward `total · (G−1)` — the per-layer planner's
    /// effective-`D` signal (`SchedCtx::plan_input_for_layer`).
    pub fn bottleneck_remote_tokens(&self, placement: &Placement) -> f64 {
        let g = placement.gpus();
        let mut sent = vec![0.0f64; g];
        let mut recv = vec![0.0f64; g];
        for (i, row) in self.tokens.iter().enumerate() {
            for (e, &t) in row.iter().enumerate() {
                let h = placement.host[e];
                if h != i {
                    sent[i] += t;
                    recv[h] += t;
                }
            }
        }
        sent.iter().chain(recv.iter()).fold(0.0f64, |a, &b| a.max(b))
    }
}

/// Expert placement: which GPU hosts each expert.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// `host[e]` = GPU hosting global expert `e`.
    pub host: Vec<usize>,
    by_gpu: Vec<Vec<usize>>,
}

impl Placement {
    pub fn new(host: Vec<usize>, gpus: usize) -> Self {
        let mut by_gpu = vec![Vec::new(); gpus];
        for (e, &g) in host.iter().enumerate() {
            by_gpu[g].push(e);
        }
        Self { host, by_gpu }
    }

    /// Canonical EP placement: expert `e` on GPU `e / experts_per_gpu`.
    pub fn round_robin(gpus: usize, experts_per_gpu: usize) -> Self {
        let host = (0..gpus * experts_per_gpu).map(|e| e / experts_per_gpu).collect();
        Self::new(host, gpus)
    }

    pub fn experts_on(&self, gpu: usize) -> &[usize] {
        &self.by_gpu[gpu]
    }

    pub fn gpus(&self) -> usize {
        self.by_gpu.len()
    }

    pub fn total_experts(&self) -> usize {
        self.host.len()
    }

    /// Swap hosts of two experts (SmartMoE-style placement search).
    pub fn swap(&mut self, e1: usize, e2: usize) {
        let (g1, g2) = (self.host[e1], self.host[e2]);
        if g1 == g2 {
            return;
        }
        self.by_gpu[g1].retain(|&e| e != e1);
        self.by_gpu[g2].retain(|&e| e != e2);
        self.by_gpu[g1].push(e2);
        self.by_gpu[g2].push(e1);
        self.by_gpu[g1].sort_unstable();
        self.by_gpu[g2].sort_unstable();
        self.host[e1] = g2;
        self.host[e2] = g1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit;

    #[test]
    fn uniform_conserves_tokens() {
        let r = Routing::uniform(4, 8, 100, 2);
        for row in &r.per_gpu_tokens() {
            assert!((row - 200.0).abs() < 1e-9);
        }
        for l in r.per_expert_load() {
            assert!((l - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn zipf_conserves_and_skews() {
        let r = Routing::zipf(4, 8, 100, 2, 1.5, 7);
        for row in &r.per_gpu_tokens() {
            assert!((row - 200.0).abs() < 1e-6);
        }
        let load = r.per_expert_load();
        let max = load.iter().cloned().fold(0.0, f64::max);
        let min = load.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max > 3.0 * min, "zipf 1.5 should skew: {load:?}");
    }

    #[test]
    fn round_robin_placement() {
        let p = Placement::round_robin(4, 2);
        assert_eq!(p.host, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(p.experts_on(2), &[4, 5]);
    }

    #[test]
    fn swap_keeps_partition() {
        testkit::check("placement-swap", 50, |g| {
            let gpus = g.usize_in(2, 6);
            let epg = g.usize_in(1, 4);
            let mut p = Placement::round_robin(gpus, epg);
            let total = p.total_experts();
            for _ in 0..10 {
                let (a, b) = (g.rng.below(total), g.rng.below(total));
                p.swap(a, b);
            }
            // every expert hosted exactly once
            let mut seen = vec![0usize; total];
            for gpu in 0..gpus {
                for &e in p.experts_on(gpu) {
                    seen[e] += 1;
                    prop_assert!(p.host[e] == gpu, "host inconsistent for expert {e}");
                }
            }
            prop_assert!(seen.iter().all(|&c| c == 1), "expert lost/duplicated: {seen:?}");
            Ok(())
        });
    }

    #[test]
    fn tokens_to_gpu_matches_manual_sum() {
        let r = Routing::uniform(2, 4, 100, 1);
        let p = Placement::round_robin(2, 2);
        // experts 2,3 on GPU 1; uniform 25 tokens each
        assert!((r.tokens_to_gpu(0, 1, &p) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_remote_tokens_uniform_and_concentrated() {
        // uniform: every GPU sends and receives total·(G−1)/G
        let r = Routing::uniform(8, 8, 100, 2);
        let p = Placement::round_robin(8, 1);
        let want = 200.0 * 7.0 / 8.0;
        assert!((r.bottleneck_remote_tokens(&p) - want).abs() < 1e-9);
        // everything routed to expert 0: its host receives 7 full rows
        let mut tokens = vec![vec![0.0; 8]; 8];
        for row in tokens.iter_mut() {
            row[0] = 200.0;
        }
        let r = Routing { tokens };
        assert!((r.bottleneck_remote_tokens(&p) - 7.0 * 200.0).abs() < 1e-9);
    }
}
