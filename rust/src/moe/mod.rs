//! MoE workload description: model/iteration parameters (paper Tables II/III
//! vocabulary), gate routing distributions, and token/traffic accounting.

pub mod routing;

pub use routing::Routing;

/// One MoE training workload as the schedulers and the stream model see it.
///
/// `D` (data leaving one GPU per MoE layer) = `tokens_per_gpu · hidden · 4`;
/// `P_E` (one expert) = `2 · hidden · ffn · 4`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MoEWorkload {
    /// Tokens produced per GPU per iteration (B·L of Table III).
    pub tokens_per_gpu: usize,
    /// Hidden dimension `H`.
    pub hidden: usize,
    /// Expert FFN dimension `M`.
    pub ffn: usize,
    /// Experts hosted per GPU (`n`).
    pub experts_per_gpu: usize,
    /// Activated experts per token (`K`).
    pub k: usize,
    /// MoE blocks per iteration (`#Layers` of Table II that carry MoE).
    pub moe_layers: usize,
    /// Transformer blocks before each MoE block (`m` of Eq. 2).
    pub pre_blocks: usize,
    /// Include the backward pass (2× compute, mirrored comms, + DDP
    /// All-Reduce for the dense part).
    pub backward: bool,
}

/// GPU compute capability for the linear GeMM model (Eq. 1): effective
/// multiply-accumulate throughput `C` in MAC/s.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub macs_per_sec: f64,
}

impl GpuSpec {
    /// A800-class effective throughput for the paper's workload mix.
    pub fn a800() -> Self {
        Self { macs_per_sec: 60e12 }
    }
}

pub const BYTES_PER_ELEM: f64 = 4.0; // f32 on the wire, as in the paper

impl MoEWorkload {
    /// Paper-default shape used by several benches (Table III mid-point).
    pub fn default_paper() -> Self {
        Self {
            tokens_per_gpu: 16 * 256, // B=16, L=256
            hidden: 1024,
            ffn: 2048,
            experts_per_gpu: 1,
            k: 2,
            moe_layers: 12,
            pre_blocks: 1,
            backward: true,
        }
    }

    /// `D`: bytes of activations leaving one GPU per MoE layer.
    pub fn d_bytes(&self) -> f64 {
        self.tokens_per_gpu as f64 * self.hidden as f64 * BYTES_PER_ELEM
    }

    /// `P_E`: bytes of one (uncompressed) expert.
    pub fn pe_bytes(&self) -> f64 {
        2.0 * self.hidden as f64 * self.ffn as f64 * BYTES_PER_ELEM
    }

    /// MACs of one token through one expert (two GeMMs: H×M + M×H).
    pub fn expert_macs_per_token(&self) -> f64 {
        2.0 * self.hidden as f64 * self.ffn as f64
    }

    /// Pre-expert computation MACs per GPU per MoE layer: `m+1` attention
    /// blocks + `m` dense FFNs (Eq. 2's `Lat^PE` numerator), linearized.
    pub fn pre_expert_macs(&self) -> f64 {
        let t = self.tokens_per_gpu as f64;
        let h = self.hidden as f64;
        let attn = 4.0 * t * h * h; // qkv+o projections dominate
        let ffn = 2.0 * t * h * self.ffn as f64;
        (self.pre_blocks as f64 + 1.0) * attn + self.pre_blocks as f64 * ffn
    }

    pub fn lat_pre_expert(&self, gpu: &GpuSpec) -> f64 {
        self.pre_expert_macs() / gpu.macs_per_sec
    }

    /// Per-expert computation latency `Lat^Ep` for an even token share
    /// (`tokens·K/E_total` tokens per expert), Eq. 1 linear model.
    pub fn lat_per_expert(&self, gpu: &GpuSpec, total_gpus: usize) -> f64 {
        let total_experts = (self.experts_per_gpu * total_gpus) as f64;
        let tokens_per_expert =
            self.tokens_per_gpu as f64 * total_gpus as f64 * self.k as f64 / total_experts;
        tokens_per_expert * self.expert_macs_per_token() / gpu.macs_per_sec
    }

    /// View as stream-model planner input (`model::solver::PlanInput`).
    pub fn plan_input(
        &self,
        gpu: &GpuSpec,
        total_gpus: usize,
        pe_tx_bytes: f64,
    ) -> crate::model::solver::PlanInput {
        crate::model::solver::PlanInput {
            d_bytes: self.d_bytes() * self.k as f64,
            pe_bytes: pe_tx_bytes,
            n_experts: self.experts_per_gpu,
            lat_pe: self.lat_pre_expert(gpu),
            lat_ep: self.lat_per_expert(gpu, total_gpus),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        let w = MoEWorkload {
            tokens_per_gpu: 1024,
            hidden: 512,
            ffn: 1024,
            experts_per_gpu: 2,
            k: 1,
            moe_layers: 4,
            pre_blocks: 1,
            backward: false,
        };
        assert_eq!(w.d_bytes(), 1024.0 * 512.0 * 4.0);
        assert_eq!(w.pe_bytes(), 2.0 * 512.0 * 1024.0 * 4.0);
    }

    #[test]
    fn per_expert_latency_scales_with_tokens() {
        let gpu = GpuSpec::a800();
        let mut w = MoEWorkload::default_paper();
        let a = w.lat_per_expert(&gpu, 8);
        w.tokens_per_gpu *= 2;
        assert!((w.lat_per_expert(&gpu, 8) - 2.0 * a).abs() < 1e-15);
    }

    #[test]
    fn total_expert_compute_invariant_under_gpus() {
        // tokens/expert × total experts is constant per GPU count scaling
        let gpu = GpuSpec::a800();
        let w = MoEWorkload::default_paper();
        let l8 = w.lat_per_expert(&gpu, 8) * (8 * w.experts_per_gpu) as f64;
        let l16 = w.lat_per_expert(&gpu, 16) * (16 * w.experts_per_gpu) as f64;
        assert!((l16 / l8 - 2.0).abs() < 1e-12); // 2× tokens overall
    }

    #[test]
    fn plan_input_consistent() {
        let w = MoEWorkload::default_paper();
        let gpu = GpuSpec::a800();
        let pi = w.plan_input(&gpu, 16, w.pe_bytes());
        assert_eq!(pi.n_experts, w.experts_per_gpu);
        assert!(pi.lat_pe > 0.0 && pi.lat_ep > 0.0);
        assert_eq!(pi.d_bytes, w.d_bytes() * w.k as f64);
    }
}
