//! Aggregated-flow schedules for very large clusters (Fig. 17: up to 1000
//! DCs).
//!
//! At DC granularity with uniform routing, per-pair A2A flows are symmetric;
//! under max-min fairness the pairwise pattern is rate-equivalent to a ring
//! shift where each GPU's total egress rides one aggregate flow (same egress
//! and ingress load on every node). That collapses O(G²) transfers to O(G),
//! keeping 1000-DC simulations tractable — the same modeling granularity the
//! paper uses for its SimAI study (one GPU per DC, §III).
//!
//! [`DcDense`] extends the scale axis to **multiple GPUs per DC** (the fig17
//! `per_dc` rows): the ring equivalence breaks there (most ring edges would
//! be intra-DC and under-count the shared uplink), so it emits the true
//! dense pattern with its symmetric cross-DC members born folded into
//! multiplicity-weighted [`MacroFlow`] bundles — ~O(D²) materialized flows
//! standing for the O(G²) member set.

use super::{SchedCtx, System};
use crate::plan::{CommPhase, Flow, LayerPlan, MacroFlow, MigratePlan, Plan, Round};

/// Aggregate HybridEP at a single level: domain size `s_ed` over `G` flat
/// workers; `s_ed = 1` is aggregate vanilla EP.
#[derive(Clone, Copy, Debug)]
pub struct AggregateHybrid {
    pub s_ed: usize,
    /// transmitted expert bytes (post-compression); `None` = raw `P_E`
    pub pe_tx_bytes: Option<f64>,
    /// per-peer message setup overhead (NCCL channel setup / kernel launch /
    /// connection amortization). This carries Table VII's *frequency* effect:
    /// EP pays `G−1` setups per A2A round, HybridEP only `G/S_ED − 1`.
    pub msg_overhead_secs: f64,
}

/// Cross-DC per-message setup cost (conservative WAN-connection estimate).
pub const DEFAULT_MSG_OVERHEAD: f64 = 100e-6;

impl AggregateHybrid {
    pub fn ep() -> Self {
        Self { s_ed: 1, pe_tx_bytes: None, msg_overhead_secs: DEFAULT_MSG_OVERHEAD }
    }

    pub fn hybrid(s_ed: usize, pe_tx_bytes: f64) -> Self {
        Self { s_ed, pe_tx_bytes: Some(pe_tx_bytes), msg_overhead_secs: DEFAULT_MSG_OVERHEAD }
    }

    /// Hybrid configured by target data proportion `p` instead of a domain
    /// size: picks the divisor `S_ED` of `g` (including `S_ED = 1`, i.e.
    /// pure EP with `p = 1`) whose `p(S_ED)` per the §V-B mapping is closest
    /// to the requested `p` (sweep grids vary `p` continuously while only
    /// divisor domains are deployable). `p ≥ 1` degenerates to EP.
    pub fn with_p(g: usize, p: f64, pe_tx_bytes: f64) -> Self {
        if p >= 1.0 || g < 2 {
            return Self::ep();
        }
        let mut best = g; // full domain (p = 0) is always a divisor
        let mut best_d = (crate::model::solver::p_of_domain(g, g) - p).abs();
        for s in 1..g {
            if g % s != 0 {
                continue;
            }
            let d = (crate::model::solver::p_of_domain(g, s) - p).abs();
            if d < best_d {
                best_d = d;
                best = s;
            }
        }
        if best == 1 {
            return Self::ep();
        }
        Self::hybrid(best, pe_tx_bytes)
    }

    /// Data proportion still on A2A (§V-B mapping).
    pub fn p(&self, g: usize) -> f64 {
        crate::model::solver::p_of_domain(g, self.s_ed)
    }
}

impl System for AggregateHybrid {
    fn name(&self) -> &'static str {
        if self.s_ed == 1 {
            "EP(agg)"
        } else {
            "HybridEP(agg)"
        }
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let g = ctx.gpus();
        assert!(g % self.s_ed == 0, "S_ED must divide G");
        let w = ctx.workload;
        let p = self.p(g);
        let d = w.d_bytes() * w.k as f64;
        let pe = self.pe_tx_bytes.unwrap_or_else(|| w.pe_bytes());
        let a2a_bytes = p * d * (g as f64 - 1.0) / g as f64;
        let ag_bytes = (self.s_ed as f64 - 1.0) * w.experts_per_gpu as f64 * pe;
        // tokens each GPU computes: conserved (uniform routing)
        let expert_secs = ctx.expert_secs((w.tokens_per_gpu * w.k) as f64);

        let domains = g / self.s_ed;
        // Table VII frequency effect: per-peer setup cost paid serially on
        // the sender (EP: G−1 peers; HybridEP: domains−1 A2A mirrors and
        // S_ED−1 AG peers, whose setup rides the asynchronous communicator).
        let a2a_setup = self.msg_overhead_secs
            * if self.s_ed == 1 { (g - 1) as f64 } else { (domains - 1) as f64 };
        let ag_setup = self.msg_overhead_secs * (self.s_ed - 1) as f64;

        // AG prefetch: ring within the domain, overlaps pre-expert compute
        let mut ag_flows = Vec::new();
        if ag_bytes > 0.0 {
            for i in 0..g {
                let dom = i / self.s_ed;
                let off = i % self.s_ed;
                let dst = dom * self.s_ed + (off + 1) % self.s_ed;
                ag_flows.push(Flow { src: i, dst, bytes: ag_bytes });
            }
        }
        // aggregate A2A: ring shift to the same-offset mirror in the next
        // domain (combine is the lowering's reverse retrace: the mirror in
        // the previous domain)
        let mut disp_flows = Vec::new();
        if a2a_bytes > 0.0 && domains > 1 {
            for i in 0..g {
                let dom = i / self.s_ed;
                let off = i % self.s_ed;
                let dst = ((dom + 1) % domains) * self.s_ed + off;
                disp_flows.push(Flow { src: i, dst, bytes: a2a_bytes });
            }
        }

        let layer = LayerPlan {
            migrate: MigratePlan {
                prologue_secs: None,
                prologue_label: "",
                phases: if ag_flows.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase {
                        flows: ag_flows,
                        setup_secs: ag_setup,
                        label: "ag",
                        ..Default::default()
                    }]
                },
            },
            pre_secs: vec![ctx.pre_expert_secs(); g],
            rounds: vec![Round {
                dispatch: if disp_flows.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase {
                        flows: disp_flows,
                        setup_secs: a2a_setup,
                        label: "dispatch",
                        ..Default::default()
                    }]
                },
                expert_secs: vec![expert_secs; g],
            }],
            tp_sync: None,
        };
        Plan { gpus: g, layers: vec![layer; w.moe_layers] }
    }
}

/// Symmetry-folded dense schedules for `dcs × per_dc` clusters — the fig17
/// `per_dc` axis at 1024 DCs × {4, 8} GPUs/DC.
///
/// [`AggregateHybrid`]'s O(G) ring rests on one GPU per DC (each worker's
/// whole egress rides its own uplink); with `per_dc > 1` a ring shift sends
/// most traffic to *intra-DC* neighbours and under-counts the shared uplink
/// by `per_dc`×. `DcDense` instead emits the **true dense** pattern with its
/// symmetric cross-DC members born folded ([`MacroFlow`], HybridEP §5's
/// domain symmetry):
///
/// * **EP** (`s_ed_gpus == 1`): dense A2A — one count-`per_dc²` bundle per
///   ordered DC pair (the O(G²) member set collapses to ~O(D²)) plus plain
///   intra-DC flows; per-peer setup `(G−1)·ovh` folded into pre compute
///   (Table VII frequency tax).
/// * **Hybrid** (`s_ed_gpus = s_ed_dcs · per_dc`): dense AllGather inside
///   each expert domain (cross-DC pairs folded, `per_dc²` members each) and
///   a mirror-shift A2A to the same-offset GPU of the next domain, folded
///   per DC (`per_dc` members per uplink); setup `(domains−1 + S−1)·ovh`.
///
/// All folded phases are [`collective`](CommPhase::collective), matching
/// synchronized NCCL A2A/AG — which is also what makes the representative
/// endpoints exact: the workload is uniform, so every member source reaches
/// the phase simultaneously. For the same reason folded phases must keep the
/// default [`Sync::Bulk`](crate::plan::Sync) policy: a macro bundle's
/// members are *defined* by the barrier-synchronised start, so lowering
/// rejects `Sync::Window` on phases that carry macro flows.
#[derive(Clone, Copy, Debug)]
pub struct DcDense {
    pub dcs: usize,
    pub per_dc: usize,
    /// Expert-domain size in GPUs: `1` = pure EP (no migration), otherwise a
    /// multiple of `per_dc` (whole DCs — `s_ed_dcs · per_dc`).
    pub s_ed_gpus: usize,
    /// transmitted expert bytes (post-compression); `None` = raw `P_E`
    pub pe_tx_bytes: Option<f64>,
    /// per-peer message setup (Table VII frequency semantics), folded into
    /// pre compute — macro bundles cannot carry per-member setup tasks
    pub msg_overhead_secs: f64,
}

impl DcDense {
    /// Pure EP: dense A2A over all `dcs · per_dc` GPUs, folded per DC pair.
    pub fn ep(dcs: usize, per_dc: usize) -> Self {
        Self {
            dcs,
            per_dc,
            s_ed_gpus: 1,
            pe_tx_bytes: None,
            msg_overhead_secs: DEFAULT_MSG_OVERHEAD,
        }
    }

    /// Hybrid with an expert domain of `s_ed_dcs` whole DCs.
    pub fn hybrid(dcs: usize, per_dc: usize, s_ed_dcs: usize, pe_tx_bytes: f64) -> Self {
        assert!(s_ed_dcs >= 1 && dcs % s_ed_dcs == 0, "domain must tile the DCs");
        Self {
            dcs,
            per_dc,
            s_ed_gpus: s_ed_dcs * per_dc,
            pe_tx_bytes: Some(pe_tx_bytes),
            msg_overhead_secs: DEFAULT_MSG_OVERHEAD,
        }
    }

    /// Data proportion still on A2A (§V-B mapping over all GPUs; coincides
    /// with the DC-level mapping for whole-DC domains).
    pub fn p(&self) -> f64 {
        crate::model::solver::p_of_domain(self.dcs * self.per_dc, self.s_ed_gpus)
    }
}

impl System for DcDense {
    fn name(&self) -> &'static str {
        if self.s_ed_gpus == 1 {
            "EP(dc-dense)"
        } else {
            "HybridEP(dc-dense)"
        }
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let (dcs, per_dc) = (self.dcs, self.per_dc);
        let g = dcs * per_dc;
        assert_eq!(ctx.gpus(), g, "cluster shape must match dcs × per_dc");
        let s = self.s_ed_gpus;
        assert!(s == 1 || (s % per_dc == 0 && g % s == 0), "domain must be whole DCs");
        let w = ctx.workload;
        let p = self.p();
        let d = w.d_bytes() * w.k as f64;
        let pe = self.pe_tx_bytes.unwrap_or_else(|| w.pe_bytes());
        let expert_secs = ctx.expert_secs((w.tokens_per_gpu * w.k) as f64);
        let domains = g / s;
        let n_pe = w.experts_per_gpu as f64 * pe;

        let mut ag_flows = Vec::new();
        let mut ag_macros = Vec::new();
        let mut setup = 0.0;
        if s > 1 {
            // dense AllGather inside each domain: every GPU receives every
            // domain peer's experts; cross-DC member groups fold per DC pair
            let s_dcs = s / per_dc;
            for dom in 0..domains {
                let base_dc = dom * s_dcs;
                for a in 0..s_dcs {
                    for b in 0..s_dcs {
                        let (dca, dcb) = (base_dc + a, base_dc + b);
                        if a == b {
                            for i in 0..per_dc {
                                for j in 0..per_dc {
                                    if i != j {
                                        ag_flows.push(Flow {
                                            src: dca * per_dc + i,
                                            dst: dca * per_dc + j,
                                            bytes: n_pe,
                                        });
                                    }
                                }
                            }
                        } else {
                            ag_macros.push(MacroFlow {
                                src: dca * per_dc,
                                dst: dcb * per_dc,
                                bytes: n_pe,
                                count: (per_dc * per_dc) as u64,
                            });
                        }
                    }
                }
            }
            setup += (s - 1) as f64 * self.msg_overhead_secs;
        }

        let mut disp_flows = Vec::new();
        let mut disp_macros = Vec::new();
        if s == 1 {
            // dense A2A: per-pair payload d/G; cross-DC pairs fold per DC pair
            let pp = d / g as f64;
            for dca in 0..dcs {
                for dcb in 0..dcs {
                    if dca == dcb {
                        for i in 0..per_dc {
                            for j in 0..per_dc {
                                if i != j {
                                    disp_flows.push(Flow {
                                        src: dca * per_dc + i,
                                        dst: dca * per_dc + j,
                                        bytes: pp,
                                    });
                                }
                            }
                        }
                    } else {
                        disp_macros.push(MacroFlow {
                            src: dca * per_dc,
                            dst: dcb * per_dc,
                            bytes: pp,
                            count: (per_dc * per_dc) as u64,
                        });
                    }
                }
            }
            setup += (g - 1) as f64 * self.msg_overhead_secs;
        } else if domains > 1 {
            // mirror shift: each GPU's aggregate cross-domain egress goes to
            // the same-offset GPU of the next domain — all `per_dc` flows of
            // a DC share its uplink, so they fold per source DC
            let a2a_bytes = p * d * (g as f64 - 1.0) / g as f64;
            let s_dcs = s / per_dc;
            for dc in 0..dcs {
                let dst_dc = (dc + s_dcs) % dcs;
                disp_macros.push(MacroFlow {
                    src: dc * per_dc,
                    dst: dst_dc * per_dc,
                    bytes: a2a_bytes,
                    count: per_dc as u64,
                });
            }
            setup += (domains - 1) as f64 * self.msg_overhead_secs;
        }

        let layer = LayerPlan {
            migrate: MigratePlan {
                prologue_secs: None,
                prologue_label: "",
                phases: if ag_flows.is_empty() && ag_macros.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase::folded(ag_flows, ag_macros, "ag")]
                },
            },
            // per-peer connection setup rides the pre-compute stage (macro
            // bundles cannot carry per-member setup tasks)
            pre_secs: vec![ctx.pre_expert_secs() + setup; g],
            rounds: vec![Round {
                dispatch: if disp_flows.is_empty() && disp_macros.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase::folded(disp_flows, disp_macros, "dispatch")]
                },
                expert_secs: vec![expert_secs; g],
            }],
            tp_sync: None,
        };
        Plan { gpus: g, layers: vec![layer; w.moe_layers] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::moe::{MoEWorkload, Routing};
    use crate::systems::SchedCtx;

    fn w() -> MoEWorkload {
        MoEWorkload {
            tokens_per_gpu: 4096,
            hidden: 1024,
            ffn: 2048,
            experts_per_gpu: 1,
            k: 2,
            moe_layers: 4,
            pre_blocks: 1,
            backward: false,
        }
    }

    #[test]
    fn scales_to_1000_dcs_quickly() {
        let cluster = presets::flat_dcs(1000, 5.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1); // unused by aggregate
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let t0 = std::time::Instant::now();
        let ep = AggregateHybrid::ep().iteration_time(&ctx);
        let hy = AggregateHybrid::hybrid(10, w.pe_bytes() / 50.0).iteration_time(&ctx);
        assert!(t0.elapsed().as_secs_f64() < 20.0, "sim too slow: {:?}", t0.elapsed());
        assert!(hy < ep, "hybrid {hy} vs ep {ep}");
    }

    #[test]
    fn traffic_matches_eq3_eq4() {
        let cluster = presets::flat_dcs(100, 5.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let sys = AggregateHybrid { s_ed: 10, pe_tx_bytes: None, msg_overhead_secs: 0.0 };
        let dag = sys.build_iteration(&ctx);
        let g = 100.0;
        let p = sys.p(100);
        let d = w.d_bytes() * w.k as f64;
        let want_a2a = 2.0 * p * d * (g - 1.0) / g * g * w.moe_layers as f64;
        let want_ag = 9.0 * w.pe_bytes() * g * w.moe_layers as f64;
        assert!((dag.traffic_by_tag(crate::netsim::Tag::A2A) - want_a2a).abs() / want_a2a < 1e-9);
        assert!((dag.traffic_by_tag(crate::netsim::Tag::AG) - want_ag).abs() / want_ag < 1e-9);
    }

    #[test]
    fn with_p_picks_nearest_divisor_domain() {
        // g = 256: p = 0.9 sits between S_ED = 16 (p = 0.9375) and
        // S_ED = 32 (p = 0.875); 32 is closer.
        let sys = AggregateHybrid::with_p(256, 0.9, 1.0);
        assert_eq!(sys.s_ed, 32);
        // exact divisor hit
        assert_eq!(AggregateHybrid::with_p(100, 0.9, 1.0).s_ed, 10);
        // p = 1 degenerates to EP
        assert_eq!(AggregateHybrid::with_p(100, 1.0, 1.0).s_ed, 1);
        // p = 0 wants the full domain
        assert_eq!(AggregateHybrid::with_p(64, 0.0, 1.0).s_ed, 64);
        // S_ED = 1 (p = 1) is a candidate too: at g = 8, p = 0.9 is closer
        // to pure EP (dist 0.1) than to S_ED = 2 (p = 0.75, dist 0.15)
        assert_eq!(AggregateHybrid::with_p(8, 0.9, 1.0).s_ed, 1);
    }

    #[test]
    fn dc_dense_materializes_od2_flows_with_full_member_weight() {
        let (dcs, per_dc) = (8usize, 4usize);
        let cluster = presets::dcs_x_gpus(dcs, per_dc, 10.0, 128.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let g = dcs * per_dc;
        let dag = DcDense::ep(dcs, per_dc).build_iteration(&ctx);
        // dense member count: every ordered GPU pair, dispatch + combine, per layer
        let want_members = 2 * g * (g - 1) * w.moe_layers;
        assert_eq!(dag.member_transfers(), want_members);
        // materialized: cross pairs fold per DC pair
        let per_phase = dcs * (dcs - 1) + dcs * per_dc * (per_dc - 1);
        assert_eq!(dag.transfer_tasks(), 2 * per_phase * w.moe_layers);
        assert_eq!(dag.frequency_by_tag(crate::netsim::Tag::A2A), want_members);
        // member-weighted traffic matches the dense closed form
        let d = w.d_bytes() * w.k as f64;
        let want_a2a = 2.0 * d * (g as f64 - 1.0) / g as f64 * g as f64 * w.moe_layers as f64;
        let got = dag.traffic_by_tag(crate::netsim::Tag::A2A);
        assert!((got - want_a2a).abs() / want_a2a < 1e-9, "{got} vs {want_a2a}");
        // hybrid with whole-DC domains: O(D) dispatch + small folded AG
        let hy = DcDense::hybrid(dcs, per_dc, 2, w.pe_bytes() / 50.0);
        let hdag = hy.build_iteration(&ctx);
        assert!(
            hdag.transfer_tasks() < dag.transfer_tasks() / 2,
            "hybrid must materialize fewer flows: {} vs {}",
            hdag.transfer_tasks(),
            dag.transfer_tasks()
        );
        let want_ag = (hy.s_ed_gpus - 1) as f64
            * w.experts_per_gpu as f64
            * (w.pe_bytes() / 50.0)
            * g as f64
            * w.moe_layers as f64;
        let got_ag = hdag.traffic_by_tag(crate::netsim::Tag::AG);
        assert!((got_ag - want_ag).abs() / want_ag < 1e-9, "{got_ag} vs {want_ag}");
    }

    /// At one GPU per DC the dense folded schedule and the aggregate ring
    /// are rate-equivalent under max-min fairness (same per-uplink load), so
    /// the two EP models must simulate to the same makespan.
    #[test]
    fn dc_dense_ep_matches_aggregate_ring_at_one_gpu_per_dc() {
        let dcs = 24usize;
        let cluster = presets::flat_dcs(dcs, 5.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let ring = AggregateHybrid::ep().iteration_time(&ctx);
        let dense = DcDense::ep(dcs, 1).iteration_time(&ctx);
        assert!(
            (dense - ring).abs() / ring < 1e-6,
            "dense folded EP {dense} vs aggregate ring EP {ring}"
        );
        // hybrid: dense folded AG vs ring AG differ only in setup placement
        let pe_tx = w.pe_bytes() / 50.0;
        let ring_hy = AggregateHybrid::hybrid(6, pe_tx).iteration_time(&ctx);
        let dense_hy = DcDense::hybrid(dcs, 1, 6, pe_tx).iteration_time(&ctx);
        assert!(
            (dense_hy - ring_hy).abs() / ring_hy < 0.1,
            "dense folded hybrid {dense_hy} vs aggregate ring hybrid {ring_hy}"
        );
    }

    #[test]
    fn dc_dense_hybrid_beats_ep_at_per_dc_scale() {
        // 64 DCs × 4 GPUs at 5 Gbps: the domain cuts both the per-peer
        // setup frequency (Table VII) and the cross-DC data share
        let (dcs, per_dc) = (64usize, 4usize);
        let cluster = presets::dcs_x_gpus(dcs, per_dc, 5.0, presets::PCIE_GBPS);
        let mut w = w();
        w.moe_layers = 1;
        let routing = Routing::uniform(1, 1, 1, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let ep = DcDense::ep(dcs, per_dc).iteration_time(&ctx);
        let hy = DcDense::hybrid(dcs, per_dc, 8, w.pe_bytes() / 50.0).iteration_time(&ctx);
        assert!(hy < ep, "hybrid {hy} must beat EP {ep} on shared uplinks");
        assert!(ep / hy < 20.0, "speedup {} implausibly large", ep / hy);
    }

    #[test]
    fn ep_matches_pairwise_ep_at_small_scale() {
        // aggregate ring A2A ≈ pairwise A2A under uniform symmetric load
        let cluster = presets::flat_dcs(8, 10.0);
        let w = w();
        let routing = Routing::uniform(8, 8, w.tokens_per_gpu, w.k);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let agg = AggregateHybrid::ep().iteration_time(&ctx);
        let pair = crate::systems::ep::VanillaEp.iteration_time(&ctx);
        let ratio = agg / pair;
        assert!((0.7..1.3).contains(&ratio), "aggregate {agg} vs pairwise {pair}");
    }
}
