//! Aggregated-flow schedules for very large clusters (Fig. 17: up to 1000
//! DCs).
//!
//! At DC granularity with uniform routing, per-pair A2A flows are symmetric;
//! under max-min fairness the pairwise pattern is rate-equivalent to a ring
//! shift where each GPU's total egress rides one aggregate flow (same egress
//! and ingress load on every node). That collapses O(G²) transfers to O(G),
//! keeping 1000-DC simulations tractable — the same modeling granularity the
//! paper uses for its SimAI study (one GPU per DC, §III).

use super::{SchedCtx, System};
use crate::plan::{CommPhase, Flow, LayerPlan, MigratePlan, Plan, Round};

/// Aggregate HybridEP at a single level: domain size `s_ed` over `G` flat
/// workers; `s_ed = 1` is aggregate vanilla EP.
#[derive(Clone, Copy, Debug)]
pub struct AggregateHybrid {
    pub s_ed: usize,
    /// transmitted expert bytes (post-compression); `None` = raw `P_E`
    pub pe_tx_bytes: Option<f64>,
    /// per-peer message setup overhead (NCCL channel setup / kernel launch /
    /// connection amortization). This carries Table VII's *frequency* effect:
    /// EP pays `G−1` setups per A2A round, HybridEP only `G/S_ED − 1`.
    pub msg_overhead_secs: f64,
}

/// Cross-DC per-message setup cost (conservative WAN-connection estimate).
pub const DEFAULT_MSG_OVERHEAD: f64 = 100e-6;

impl AggregateHybrid {
    pub fn ep() -> Self {
        Self { s_ed: 1, pe_tx_bytes: None, msg_overhead_secs: DEFAULT_MSG_OVERHEAD }
    }

    pub fn hybrid(s_ed: usize, pe_tx_bytes: f64) -> Self {
        Self { s_ed, pe_tx_bytes: Some(pe_tx_bytes), msg_overhead_secs: DEFAULT_MSG_OVERHEAD }
    }

    /// Hybrid configured by target data proportion `p` instead of a domain
    /// size: picks the divisor `S_ED` of `g` (including `S_ED = 1`, i.e.
    /// pure EP with `p = 1`) whose `p(S_ED)` per the §V-B mapping is closest
    /// to the requested `p` (sweep grids vary `p` continuously while only
    /// divisor domains are deployable). `p ≥ 1` degenerates to EP.
    pub fn with_p(g: usize, p: f64, pe_tx_bytes: f64) -> Self {
        if p >= 1.0 || g < 2 {
            return Self::ep();
        }
        let mut best = g; // full domain (p = 0) is always a divisor
        let mut best_d = (crate::model::solver::p_of_domain(g, g) - p).abs();
        for s in 1..g {
            if g % s != 0 {
                continue;
            }
            let d = (crate::model::solver::p_of_domain(g, s) - p).abs();
            if d < best_d {
                best_d = d;
                best = s;
            }
        }
        if best == 1 {
            return Self::ep();
        }
        Self::hybrid(best, pe_tx_bytes)
    }

    /// Data proportion still on A2A (§V-B mapping).
    pub fn p(&self, g: usize) -> f64 {
        crate::model::solver::p_of_domain(g, self.s_ed)
    }
}

impl System for AggregateHybrid {
    fn name(&self) -> &'static str {
        if self.s_ed == 1 {
            "EP(agg)"
        } else {
            "HybridEP(agg)"
        }
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let g = ctx.gpus();
        assert!(g % self.s_ed == 0, "S_ED must divide G");
        let w = ctx.workload;
        let p = self.p(g);
        let d = w.d_bytes() * w.k as f64;
        let pe = self.pe_tx_bytes.unwrap_or_else(|| w.pe_bytes());
        let a2a_bytes = p * d * (g as f64 - 1.0) / g as f64;
        let ag_bytes = (self.s_ed as f64 - 1.0) * w.experts_per_gpu as f64 * pe;
        // tokens each GPU computes: conserved (uniform routing)
        let expert_secs = ctx.expert_secs((w.tokens_per_gpu * w.k) as f64);

        let domains = g / self.s_ed;
        // Table VII frequency effect: per-peer setup cost paid serially on
        // the sender (EP: G−1 peers; HybridEP: domains−1 A2A mirrors and
        // S_ED−1 AG peers, whose setup rides the asynchronous communicator).
        let a2a_setup = self.msg_overhead_secs
            * if self.s_ed == 1 { (g - 1) as f64 } else { (domains - 1) as f64 };
        let ag_setup = self.msg_overhead_secs * (self.s_ed - 1) as f64;

        // AG prefetch: ring within the domain, overlaps pre-expert compute
        let mut ag_flows = Vec::new();
        if ag_bytes > 0.0 {
            for i in 0..g {
                let dom = i / self.s_ed;
                let off = i % self.s_ed;
                let dst = dom * self.s_ed + (off + 1) % self.s_ed;
                ag_flows.push(Flow { src: i, dst, bytes: ag_bytes });
            }
        }
        // aggregate A2A: ring shift to the same-offset mirror in the next
        // domain (combine is the lowering's reverse retrace: the mirror in
        // the previous domain)
        let mut disp_flows = Vec::new();
        if a2a_bytes > 0.0 && domains > 1 {
            for i in 0..g {
                let dom = i / self.s_ed;
                let off = i % self.s_ed;
                let dst = ((dom + 1) % domains) * self.s_ed + off;
                disp_flows.push(Flow { src: i, dst, bytes: a2a_bytes });
            }
        }

        let layer = LayerPlan {
            migrate: MigratePlan {
                prologue_secs: None,
                prologue_label: "",
                phases: if ag_flows.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase { flows: ag_flows, setup_secs: ag_setup, label: "ag" }]
                },
            },
            pre_secs: vec![ctx.pre_expert_secs(); g],
            rounds: vec![Round {
                dispatch: if disp_flows.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase { flows: disp_flows, setup_secs: a2a_setup, label: "dispatch" }]
                },
                expert_secs: vec![expert_secs; g],
            }],
            tp_sync: None,
        };
        Plan { gpus: g, layers: vec![layer; w.moe_layers] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::moe::{MoEWorkload, Routing};
    use crate::systems::SchedCtx;

    fn w() -> MoEWorkload {
        MoEWorkload {
            tokens_per_gpu: 4096,
            hidden: 1024,
            ffn: 2048,
            experts_per_gpu: 1,
            k: 2,
            moe_layers: 4,
            pre_blocks: 1,
            backward: false,
        }
    }

    #[test]
    fn scales_to_1000_dcs_quickly() {
        let cluster = presets::flat_dcs(1000, 5.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1); // unused by aggregate
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let t0 = std::time::Instant::now();
        let ep = AggregateHybrid::ep().iteration_time(&ctx);
        let hy = AggregateHybrid::hybrid(10, w.pe_bytes() / 50.0).iteration_time(&ctx);
        assert!(t0.elapsed().as_secs_f64() < 20.0, "sim too slow: {:?}", t0.elapsed());
        assert!(hy < ep, "hybrid {hy} vs ep {ep}");
    }

    #[test]
    fn traffic_matches_eq3_eq4() {
        let cluster = presets::flat_dcs(100, 5.0);
        let w = w();
        let routing = Routing::uniform(1, 1, 1, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let sys = AggregateHybrid { s_ed: 10, pe_tx_bytes: None, msg_overhead_secs: 0.0 };
        let dag = sys.build_iteration(&ctx);
        let g = 100.0;
        let p = sys.p(100);
        let d = w.d_bytes() * w.k as f64;
        let want_a2a = 2.0 * p * d * (g - 1.0) / g * g * w.moe_layers as f64;
        let want_ag = 9.0 * w.pe_bytes() * g * w.moe_layers as f64;
        assert!((dag.traffic_by_tag(crate::netsim::Tag::A2A) - want_a2a).abs() / want_a2a < 1e-9);
        assert!((dag.traffic_by_tag(crate::netsim::Tag::AG) - want_ag).abs() / want_ag < 1e-9);
    }

    #[test]
    fn with_p_picks_nearest_divisor_domain() {
        // g = 256: p = 0.9 sits between S_ED = 16 (p = 0.9375) and
        // S_ED = 32 (p = 0.875); 32 is closer.
        let sys = AggregateHybrid::with_p(256, 0.9, 1.0);
        assert_eq!(sys.s_ed, 32);
        // exact divisor hit
        assert_eq!(AggregateHybrid::with_p(100, 0.9, 1.0).s_ed, 10);
        // p = 1 degenerates to EP
        assert_eq!(AggregateHybrid::with_p(100, 1.0, 1.0).s_ed, 1);
        // p = 0 wants the full domain
        assert_eq!(AggregateHybrid::with_p(64, 0.0, 1.0).s_ed, 64);
        // S_ED = 1 (p = 1) is a candidate too: at g = 8, p = 0.9 is closer
        // to pure EP (dist 0.1) than to S_ED = 2 (p = 0.75, dist 0.15)
        assert_eq!(AggregateHybrid::with_p(8, 0.9, 1.0).s_ed, 1);
    }

    #[test]
    fn ep_matches_pairwise_ep_at_small_scale() {
        // aggregate ring A2A ≈ pairwise A2A under uniform symmetric load
        let cluster = presets::flat_dcs(8, 10.0);
        let w = w();
        let routing = Routing::uniform(8, 8, w.tokens_per_gpu, w.k);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let agg = AggregateHybrid::ep().iteration_time(&ctx);
        let pair = crate::systems::ep::VanillaEp.iteration_time(&ctx);
        let ratio = agg / pair;
        assert!((0.7..1.3).contains(&ratio), "aggregate {agg} vs pairwise {pair}");
    }
}
