//! Vanilla Expert Parallelism and Tutel-style chunked pipelining.
//!
//! Vanilla EP (the paper's Fig. 1/Fig. 3(a) baseline): per MoE layer, each
//! GPU runs pre-expert compute, dispatches tokens to expert hosts with a
//! blocking A2A, computes its experts on arrivals, and returns results with a
//! second A2A.
//!
//! [`Tutel`] splits dispatch/expert/combine into `r` chunks so chunk `c+1`'s
//! A2A overlaps chunk `c`'s expert compute (adaptive pipelining of [22],
//! [46]). `r = 1` degenerates to vanilla EP.

use super::{SchedCtx, System};
use crate::moe::routing::Placement;
use crate::plan::{CommPhase, Flow, LayerPlan, MigratePlan, Plan, Round};

/// Blocking EP baseline.
#[derive(Clone, Copy, Debug, Default)]
pub struct VanillaEp;

impl System for VanillaEp {
    fn name(&self) -> &'static str {
        "VanillaEP"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        plan_pipelined(ctx, 1, None)
    }
}

/// Tutel-style adaptive pipelining ([22]): overlap chunked A2A with expert
/// compute. The chunk count is the paper's pipeline degree.
#[derive(Clone, Copy, Debug)]
pub struct Tutel {
    pub chunks: usize,
}

impl Default for Tutel {
    fn default() -> Self {
        Self { chunks: 4 }
    }
}

impl System for Tutel {
    fn name(&self) -> &'static str {
        "Tutel"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        plan_pipelined(ctx, self.chunks, None)
    }
}

/// Shared EP layer planner, parameterized by pipeline degree and an optional
/// expert placement (SmartMoE reuses it with a searched placement). Each
/// pipeline chunk becomes one Plan-IR round: a single dispatch phase, expert
/// compute on arrivals, combine retracing the dispatch.
///
/// All phases are emitted with the default [`crate::plan::Sync::Bulk`]
/// policy — the EP baselines are deliberately bulk-synchronous; overlap is
/// what Tutel-style chunking (and, at the schedule level,
/// `Sync::Window`/pipeline parallelism) buys back. Chunks whose dispatch has
/// no remote flows (ep = 1 virtual ranks, fully local routing) emit an empty
/// `dispatch` phase list rather than an empty `CommPhase`, so lowering adds
/// no barrier-only nodes for them.
pub(crate) fn plan_pipelined(ctx: &SchedCtx, chunks: usize, placement: Option<&Placement>) -> Plan {
    let g = ctx.gpus();
    let default_placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
    let placement = placement.unwrap_or(&default_placement);
    let frac = 1.0 / chunks as f64;

    let mut layers = Vec::new();
    for layer in 0..ctx.workload.moe_layers {
        let routing = ctx.routing_for(layer);
        let mut rounds = Vec::new();
        for _c in 0..chunks {
            // token matrix: tokens[i][j] routed from GPU i to experts on j
            let mut flows = Vec::new();
            for i in 0..g {
                for j in 0..g {
                    let tokens = routing.tokens_to_gpu(i, j, placement) * frac;
                    if i == j || tokens <= 0.0 {
                        continue;
                    }
                    flows.push(Flow { src: i, dst: j, bytes: ctx.token_bytes(tokens) });
                }
            }
            // expert compute on each host (local + arrived tokens)
            let expert_secs: Vec<f64> = (0..g)
                .map(|j| {
                    let total: f64 = (0..g)
                        .map(|i| routing.tokens_to_gpu(i, j, placement))
                        .sum::<f64>()
                        * frac;
                    ctx.expert_secs(total)
                })
                .collect();
            let dispatch = if flows.is_empty() {
                Vec::new()
            } else {
                vec![CommPhase::new(flows, "dispatch")]
            };
            rounds.push(Round { dispatch, expert_secs });
        }
        layers.push(LayerPlan {
            migrate: MigratePlan::none(),
            pre_secs: vec![ctx.pre_expert_secs(); g],
            rounds,
            tp_sync: None,
        });
    }
    Plan { gpus: g, layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Dag, Simulator, Tag};
    use crate::systems::testutil::small_ctx_parts;

    #[test]
    fn pipelining_helps_or_matches() {
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let vanilla = VanillaEp.iteration_time(&ctx);
        let tutel = Tutel { chunks: 4 }.iteration_time(&ctx);
        assert!(tutel <= vanilla * 1.001, "tutel {tutel} vs vanilla {vanilla}");
    }

    #[test]
    fn a2a_traffic_matches_eq3() {
        // uniform routing: per-GPU dispatch volume = D·K·(G−1)/G, twice
        // (dispatch + combine), per layer
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let mut dag = Dag::new();
        let start = dag.barrier(vec![], "s");
        let entry = vec![start; ctx.gpus()];
        VanillaEp.build_forward(&ctx, &mut dag, &entry);
        let g = ctx.gpus() as f64;
        let d = w.d_bytes() * w.k as f64;
        let want = 2.0 * d * (g - 1.0) / g * g * w.moe_layers as f64;
        let got = dag.traffic_by_tag(Tag::A2A);
        assert!((got - want).abs() / want < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn ep_frequency_matches_table_vii() {
        // single-level 8-GPU cluster, 1 layer, fwd-only, 1 chunk:
        // 56 ordered pairs × 2 (dispatch + combine)
        let cluster = crate::cluster::presets::cluster_s();
        let w = crate::moe::MoEWorkload {
            tokens_per_gpu: 64,
            hidden: 32,
            ffn: 64,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let routing = crate::moe::Routing::uniform(8, 8, 64, 1);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let dag = VanillaEp.build_iteration(&ctx);
        assert_eq!(dag.frequency_by_tag(Tag::A2A), 2 * 56);
        assert_eq!(dag.frequency_by_tag(Tag::AG), 0);
    }

    #[test]
    fn iteration_grows_with_data() {
        let (cluster, mut w, _) = small_ctx_parts();
        let mk = |w: &crate::moe::MoEWorkload| {
            let routing = crate::moe::Routing::uniform(
                cluster.total_gpus(),
                cluster.total_gpus() * w.experts_per_gpu,
                w.tokens_per_gpu,
                w.k,
            );
            let ctx = SchedCtx::new(&cluster, w, &routing);
            let dag = VanillaEp.build_iteration(&ctx);
            Simulator::new(&cluster).run(&dag).makespan
        };
        let t1 = mk(&w);
        w.tokens_per_gpu *= 4;
        let t4 = mk(&w);
        assert!(t4 > 2.5 * t1, "A2A-bound iteration should scale with tokens: {t1} → {t4}");
    }
}
