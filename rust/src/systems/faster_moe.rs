//! FasterMoE-style dynamic shadowing ([20]).
//!
//! FasterMoE observes skewed gates create *hot* experts whose token traffic
//! dwarfs the expert's own size; it "shadows" those experts by broadcasting
//! their parameters to all GPUs so hot-expert tokens compute locally, and
//! pipelines the rest. Under even routing it degenerates to chunked EP.
//!
//! All emitted phases carry the default [`crate::plan::Sync::Bulk`] policy
//! (the historical barrier-per-phase contract); chunks with no cold remote
//! flows emit no dispatch phase at all so lowering never materialises
//! barrier-only nodes.

use super::{SchedCtx, System};
use crate::moe::routing::Placement;
use crate::plan::{CommPhase, Flow, LayerPlan, MigratePlan, Plan, Round};

#[derive(Clone, Copy, Debug)]
pub struct FasterMoe {
    /// An expert is shadowed when its load exceeds `hot_factor ×` average.
    pub hot_factor: f64,
    /// Pipeline degree for the residual A2A.
    pub chunks: usize,
}

impl Default for FasterMoe {
    fn default() -> Self {
        Self { hot_factor: 2.0, chunks: 2 }
    }
}

impl FasterMoe {
    /// Experts whose load exceeds the shadowing threshold.
    pub fn hot_experts(&self, ctx: &SchedCtx) -> Vec<usize> {
        let load = ctx.routing.per_expert_load();
        let avg = load.iter().sum::<f64>() / load.len() as f64;
        load.iter()
            .enumerate()
            .filter(|(_, &l)| l > self.hot_factor * avg)
            .map(|(e, _)| e)
            .collect()
    }
}

impl System for FasterMoe {
    fn name(&self) -> &'static str {
        "FasterMoE"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let g = ctx.gpus();
        let placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
        let hot = self.hot_experts(ctx);
        let is_hot = {
            let mut v = vec![false; placement.total_experts()];
            for &e in &hot {
                v[e] = true;
            }
            v
        };
        let pe = ctx.workload.pe_bytes();
        let frac = 1.0 / self.chunks as f64;

        let mut layers = Vec::new();
        for layer in 0..ctx.workload.moe_layers {
            let routing = ctx.routing_for(layer);
            // broadcast shadowed experts (overlaps pre-expert compute)
            let mut shadow = Vec::new();
            for &e in &hot {
                let h = placement.host[e];
                for dst in 0..g {
                    if dst != h {
                        shadow.push(Flow { src: h, dst, bytes: pe });
                    }
                }
            }
            let migrate = MigratePlan {
                prologue_secs: None,
                prologue_label: "",
                phases: if shadow.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase::new(shadow, "shadow")]
                },
            };
            // cold tokens route as chunked A2A; hot tokens compute locally
            let cold_to = |i: usize, j: usize| -> f64 {
                placement
                    .experts_on(j)
                    .iter()
                    .filter(|&&e| !is_hot[e])
                    .map(|&e| routing.tokens[i][e])
                    .sum::<f64>()
            };
            let mut rounds = Vec::new();
            for _c in 0..self.chunks {
                let mut flows = Vec::new();
                for i in 0..g {
                    for j in 0..g {
                        let tokens = cold_to(i, j) * frac;
                        if i == j || tokens <= 0.0 {
                            continue;
                        }
                        flows.push(Flow { src: i, dst: j, bytes: ctx.token_bytes(tokens) });
                    }
                }
                let expert_secs: Vec<f64> = (0..g)
                    .map(|j| {
                        let cold: f64 = (0..g).map(|i| cold_to(i, j)).sum::<f64>() * frac;
                        let local_hot: f64 =
                            hot.iter().map(|&e| routing.tokens[j][e]).sum::<f64>() * frac;
                        ctx.expert_secs(cold + local_hot)
                    })
                    .collect();
                let dispatch = if flows.is_empty() {
                    Vec::new()
                } else {
                    vec![CommPhase::new(flows, "dispatch")]
                };
                rounds.push(Round { dispatch, expert_secs });
            }
            layers.push(LayerPlan {
                migrate,
                pre_secs: vec![ctx.pre_expert_secs(); g],
                rounds,
                tp_sync: None,
            });
        }
        Plan { gpus: g, layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::moe::{MoEWorkload, Routing};
    use crate::netsim::Tag;
    use crate::systems::ep::VanillaEp;

    fn skewed_parts() -> (crate::cluster::ClusterSpec, MoEWorkload, Routing) {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 2048,
            hidden: 512,
            ffn: 512,
            experts_per_gpu: 1,
            k: 2,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let routing = Routing::zipf(8, 8, 2048, 2, 1.6, 3);
        (cluster, w, routing)
    }

    #[test]
    fn detects_hot_experts_under_zipf() {
        let (cluster, w, routing) = skewed_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let hot = FasterMoe::default().hot_experts(&ctx);
        assert!(!hot.is_empty(), "zipf 1.6 must produce hot experts");
        assert!(hot.len() < 4, "not everything is hot: {hot:?}");
    }

    #[test]
    fn no_hot_experts_under_uniform() {
        let cluster = presets::cluster_s();
        let w = MoEWorkload::default_paper();
        let routing = Routing::uniform(8, 8, w.tokens_per_gpu, w.k);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        assert!(FasterMoe::default().hot_experts(&ctx).is_empty());
    }

    #[test]
    fn shadowing_beats_vanilla_under_skew() {
        let (cluster, w, routing) = skewed_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let vanilla = VanillaEp.iteration_time(&ctx);
        let fm = FasterMoe::default().iteration_time(&ctx);
        assert!(fm < vanilla, "shadowing should win under skew: {fm} vs {vanilla}");
    }

    #[test]
    fn shadow_traffic_is_ag_tagged() {
        let (cluster, w, routing) = skewed_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let dag = FasterMoe::default().build_iteration(&ctx);
        assert!(dag.traffic_by_tag(Tag::AG) > 0.0);
    }
}
