//! MoE training systems as *schedule generators*.
//!
//! Every system consumes the same cluster + workload + routing and emits a
//! layered [`Plan`](crate::plan::Plan) for one training iteration
//! (`plan_forward`); the shared lowering pass
//! ([`plan::lower_forward`](crate::plan::lower_forward)) turns it into the
//! [`Dag`] executed by [`netsim::Simulator`](crate::netsim::Simulator) — the
//! plan → lower → simulate pipeline. This mirrors the paper's comparison:
//! identical workloads, different communication/compute schedules.
//!
//! Synchronisation contract: phases a system emits default to
//! [`Sync::Bulk`](crate::plan::Sync) — collective phases are fenced by a
//! per-phase barrier over the GPUs the phase spans, exactly the historical
//! global-barrier-per-phase behaviour. Overlap is opt-in per phase via
//! `Sync::Window`, which relaxes the *barrier* (flows contend with
//! downstream compute) but never the flow → compute data dependencies.
//!
//! * [`ep::VanillaEp`] — textbook EP: blocking A2A dispatch → expert → A2A
//!   combine (Tutel with pipeline degree 1).
//! * [`ep::Tutel`] — chunked A2A/compute pipelining ([22]).
//! * [`faster_moe::FasterMoe`] — dynamic shadowing of hot experts ([20]).
//! * [`smart_moe::SmartMoe`] — offline expert-placement search ([58]).
//! * [`hybrid_ep::HybridEp`] — this paper: model-guided domain partition +
//!   hierarchical hybrid A2A/AG with parameter-efficient migration.

pub mod aggregate;
pub mod ep;
pub mod faster_moe;
pub mod hybrid_ep;
pub mod smart_moe;

use crate::cluster::{ClusterSpec, ParallelismConfig};
use crate::model::solver::PlanInput;
use crate::moe::routing::{Placement, Routing};
use crate::moe::{GpuSpec, MoEWorkload, BYTES_PER_ELEM};
use crate::netsim::{Dag, Simulator, Tag, TaskId};
use crate::plan::Plan;

/// Everything a system needs to build a schedule.
pub struct SchedCtx<'a> {
    pub cluster: &'a ClusterSpec,
    pub workload: &'a MoEWorkload,
    pub gpu: GpuSpec,
    pub routing: &'a Routing,
    /// Optional per-MoE-layer routing trace; when set, layer `l` routes with
    /// `layer_routing[l % len]` and per-layer planners solve a `p_l` per
    /// layer. `None` = `routing` for every layer (the paper's setting).
    pub layer_routing: Option<&'a [Routing]>,
    /// Fixed per-layer, per-GPU framework time (optimizer step, data
    /// pipeline, non-MoE blocks outside the linear model). Identical for
    /// every system; calibrated against the paper's Table V intercept
    /// (~1.9 s per 12-layer iteration on A800).
    pub fixed_layer_overhead: f64,
    /// Joint PP × TP × EP × DP degrees the schedule is planned under. The
    /// identity (the default) plans pure EP over all GPUs — bit-for-bit the
    /// pre-config behaviour; non-identity configs route every system's plan
    /// through [`plan::parallel`](crate::plan::parallel). With `pp > 1` the
    /// plan carries a [`PipelineSchedule`](crate::plan::PipelineSchedule)
    /// whose stage-boundary activations are `Sync::Window` (overlapped with
    /// downstream expert compute) unless [`Self::pp_overlap`] is cleared.
    pub parallelism: ParallelismConfig,
    /// Whether pipeline stage-boundary transfers get a
    /// [`Sync::Window`](crate::plan::Sync) overlap policy (`true`, the
    /// default) or the bulk-synchronous `Sync::Bulk` baseline (`false`).
    /// Irrelevant when `parallelism.pp == 1`.
    pub pp_overlap: bool,
}

impl<'a> SchedCtx<'a> {
    pub fn new(cluster: &'a ClusterSpec, workload: &'a MoEWorkload, routing: &'a Routing) -> Self {
        Self {
            cluster,
            workload,
            gpu: GpuSpec::a800(),
            routing,
            layer_routing: None,
            fixed_layer_overhead: 0.0,
            parallelism: ParallelismConfig::identity(cluster.total_gpus()),
            pp_overlap: true,
        }
    }

    /// Builder-style parallelism override; panics if the config does not
    /// factor the cluster (build configs with [`ParallelismConfig::new`]).
    pub fn with_parallelism(mut self, cfg: ParallelismConfig) -> Self {
        cfg.validate(self.cluster).expect("parallelism config incompatible with cluster");
        self.parallelism = cfg;
        self
    }

    pub fn gpus(&self) -> usize {
        self.cluster.total_gpus()
    }

    /// The routing layer `l` sees (the per-layer trace when present).
    pub fn routing_for(&self, layer: usize) -> &'a Routing {
        match self.layer_routing {
            Some(rs) if !rs.is_empty() => &rs[layer % rs.len()],
            _ => self.routing,
        }
    }

    /// Stream-model input for one layer: the layer's routing skew rescales
    /// the effective data volume `D` to the bottleneck GPU's remote traffic
    /// (uniform routing reproduces `MoEWorkload::plan_input` exactly), so
    /// skewed layers solve to different `p_l` than even ones.
    pub fn plan_input_for_layer(&self, layer: usize, pe_tx_bytes: f64) -> PlanInput {
        let mut input = self.workload.plan_input(&self.gpu, self.gpus(), pe_tx_bytes);
        let g = self.gpus();
        if g > 1 {
            let placement = Placement::round_robin(g, self.workload.experts_per_gpu);
            let bottleneck = self.routing_for(layer).bottleneck_remote_tokens(&placement);
            let bytes = bottleneck * self.workload.hidden as f64 * BYTES_PER_ELEM;
            input.d_bytes = bytes * g as f64 / (g as f64 - 1.0);
        }
        input
    }

    /// Wire bytes for `tokens` routed tokens.
    pub fn token_bytes(&self, tokens: f64) -> f64 {
        tokens * self.workload.hidden as f64 * BYTES_PER_ELEM
    }

    /// Expert-compute seconds for `tokens` tokens.
    pub fn expert_secs(&self, tokens: f64) -> f64 {
        tokens * self.workload.expert_macs_per_token() / self.gpu.macs_per_sec
    }

    pub fn pre_expert_secs(&self) -> f64 {
        self.workload.lat_pre_expert(&self.gpu) + self.fixed_layer_overhead
    }

    /// Dense (non-expert) parameter bytes per GPU — the DDP All-Reduce
    /// payload the paper treats as a constant (§VI).
    pub fn dense_param_bytes(&self) -> f64 {
        let h = self.workload.hidden as f64;
        let m = self.workload.ffn as f64;
        let blocks = (self.workload.pre_blocks + 1) as f64 * self.workload.moe_layers as f64;
        blocks * (4.0 * h * h + 2.0 * h * m) * BYTES_PER_ELEM
    }
}

/// A system = a named schedule generator.
pub trait System {
    fn name(&self) -> &'static str;

    /// Stage 1 of the plan → lower → simulate pipeline: the layered Plan IR
    /// for one **forward** pass over all MoE layers.
    fn plan_forward(&self, ctx: &SchedCtx) -> Plan;

    /// Stage 2: shared lowering of the Plan IR into a task DAG. `entry[g]`
    /// are the per-GPU entry dependencies; returns per-GPU exit tasks.
    /// Systems never construct `Dag` tasks directly — overrides of this
    /// method only post-process what the shared lowering emitted. The plan
    /// is built under `ctx.parallelism`
    /// ([`plan::parallel::planned_forward`](crate::plan::parallel::planned_forward)),
    /// so every system becomes a TED-style baseline under a non-identity
    /// config.
    fn build_forward(&self, ctx: &SchedCtx, dag: &mut Dag, entry: &[TaskId]) -> Vec<TaskId> {
        crate::plan::lower_forward(&crate::plan::parallel::planned_forward(self, ctx), dag, entry)
    }

    /// Full iteration: forward (+ backward as a mirrored pass with 2× compute
    /// and the same communication volumes, plus the overlappable dense-DDP
    /// All-Reduce — the paper's §VI treatment).
    fn build_iteration(&self, ctx: &SchedCtx) -> Dag {
        let mut dag = Dag::new();
        let g = ctx.gpus();
        let start = dag.barrier(vec![], "iter_start");
        let entry: Vec<TaskId> = (0..g).map(|_| start).collect();
        let fwd_exit = self.build_forward(ctx, &mut dag, &entry);
        if !ctx.workload.backward {
            dag.barrier(fwd_exit, "iter_end");
            return dag;
        }
        // backward: mirrored schedule with doubled compute (dgrad + wgrad)
        let bwd_entry: Vec<TaskId> = fwd_exit
            .iter()
            .enumerate()
            .map(|(gpu, &t)| dag.compute(gpu, 0.0, vec![t], "bwd_entry"))
            .collect();
        let bwd_exit = {
            let doubled = DoubledCompute(self);
            doubled.build_forward(ctx, &mut dag, &bwd_entry)
        };
        // DDP all-reduce of dense params (TP-sharded when tp > 1, and each
        // pipeline stage only holds 1/pp of the layers): ring pass,
        // overlapped with backward
        let cfg = ctx.parallelism;
        let dense = ctx.dense_param_bytes() / (cfg.tp * cfg.pp) as f64;
        let ar_bytes = 2.0 * dense * (g as f64 - 1.0) / g as f64;
        let mut ends = bwd_exit.clone();
        for i in 0..g {
            let t = dag.transfer(i, (i + 1) % g, ar_bytes, Tag::AllReduce, vec![bwd_entry[i]], "ddp");
            ends.push(t);
        }
        // expert-gradient sync across data-parallel replicas (dp > 1 only):
        // every GPU holds n·dp full-expert payloads' worth of TP shards, and
        // each expert exists once per replica — a ring across same-position
        // GPUs of the dp replicas keeps them coherent, overlapped with
        // backward like the dense ring. Replicas live inside a pipeline
        // stage, so under pp > 1 each stage block runs its own ring (pp = 1
        // degenerates to the single global ring, bit-for-bit).
        if cfg.dp > 1 {
            let gps = g / cfg.pp;
            let stride = gps / cfg.dp;
            let shard = ctx.workload.experts_per_gpu as f64
                * cfg.dp as f64
                * ctx.workload.pe_bytes();
            let hop = 2.0 * shard * (cfg.dp as f64 - 1.0) / cfg.dp as f64;
            for s in 0..cfg.pp {
                let base = s * gps;
                for q in 0..stride {
                    for r in 0..cfg.dp {
                        let src = base + r * stride + q;
                        let dst = base + ((r + 1) % cfg.dp) * stride + q;
                        let t = dag
                            .transfer(src, dst, hop, Tag::AllReduce, vec![bwd_entry[src]], "dp_sync");
                        ends.push(t);
                    }
                }
            }
        }
        dag.barrier(ends, "iter_end");
        dag
    }

    /// Simulated iteration time on the given context.
    fn iteration_time(&self, ctx: &SchedCtx) -> f64 {
        let dag = self.build_iteration(ctx);
        Simulator::new(ctx.cluster).run(&dag).makespan
    }
}

/// Wrapper that doubles compute durations (backward ≈ 2× forward FLOPs).
struct DoubledCompute<'s, S: System + ?Sized>(&'s S);

impl<'s, S: System + ?Sized> System for DoubledCompute<'s, S> {
    fn name(&self) -> &'static str {
        "doubled"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        self.0.plan_forward(ctx)
    }

    fn build_forward(&self, ctx: &SchedCtx, dag: &mut Dag, entry: &[TaskId]) -> Vec<TaskId> {
        let before = dag.len();
        let out = self.0.build_forward(ctx, dag, entry);
        for t in &mut dag.tasks[before..] {
            if let crate::netsim::TaskKind::Compute { seconds, .. } = &mut t.kind {
                *seconds *= 2.0;
            }
        }
        out
    }
}

/// All registered systems for the comparison tables.
pub fn comparison_set() -> Vec<Box<dyn System>> {
    vec![
        Box::new(ep::VanillaEp),
        Box::new(ep::Tutel::default()),
        Box::new(faster_moe::FasterMoe::default()),
        Box::new(smart_moe::SmartMoe::default()),
        Box::new(hybrid_ep::HybridEp::default()),
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::presets;
    use crate::netsim::TaskKind;

    pub fn small_ctx_parts() -> (ClusterSpec, MoEWorkload, Routing) {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 512,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 2,
            k: 2,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let routing =
            Routing::uniform(cluster.total_gpus(), cluster.total_gpus() * 2, 512, 2);
        (cluster, w, routing)
    }

    /// Total expert-compute seconds scheduled across all GPUs.
    pub fn total_expert_compute(dag: &Dag) -> f64 {
        dag.tasks
            .iter()
            .filter(|t| t.label.starts_with("expert"))
            .map(|t| match t.kind {
                TaskKind::Compute { seconds, .. } => seconds,
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    #[test]
    fn all_systems_simulate_without_deadlock() {
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        for sys in comparison_set() {
            let t = sys.iteration_time(&ctx);
            assert!(t.is_finite() && t > 0.0, "{} produced {t}", sys.name());
        }
    }

    #[test]
    fn backward_increases_time() {
        let (cluster, mut w, routing) = small_ctx_parts();
        let fwd = {
            let ctx = SchedCtx::new(&cluster, &w, &routing);
            ep::VanillaEp.iteration_time(&ctx)
        };
        w.backward = true;
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let full = ep::VanillaEp.iteration_time(&ctx);
        assert!(full > 1.8 * fwd, "fwd {fwd}, full {full}");
    }

    #[test]
    fn comparison_set_includes_blocking_ep_baseline() {
        let names: Vec<&str> = comparison_set().iter().map(|s| s.name()).collect();
        assert!(names.contains(&"VanillaEP"), "comparison set dropped the EP baseline: {names:?}");
        assert!(names.contains(&"HybridEP"));
    }

    #[test]
    fn layer_routing_trace_selects_per_layer() {
        let (cluster, w, routing) = small_ctx_parts();
        let trace = vec![
            Routing::uniform(cluster.total_gpus(), cluster.total_gpus() * 2, 512, 2),
            Routing::zipf(cluster.total_gpus(), cluster.total_gpus() * 2, 512, 2, 1.5, 9),
        ];
        let mut ctx = SchedCtx::new(&cluster, &w, &routing);
        assert!(std::ptr::eq(ctx.routing_for(0), &routing));
        ctx.layer_routing = Some(&trace);
        assert!(std::ptr::eq(ctx.routing_for(0), &trace[0]));
        assert!(std::ptr::eq(ctx.routing_for(1), &trace[1]));
        assert!(std::ptr::eq(ctx.routing_for(2), &trace[0]), "trace wraps around");
        // skewed layer must present a larger effective D to the solver
        let d0 = ctx.plan_input_for_layer(0, w.pe_bytes()).d_bytes;
        let d1 = ctx.plan_input_for_layer(1, w.pe_bytes()).d_bytes;
        assert!(d1 > d0 * 1.05, "zipf layer should raise effective D: {d0} vs {d1}");
        // and the uniform layer reproduces the global plan input
        let global = w.plan_input(&ctx.gpu, ctx.gpus(), w.pe_bytes());
        assert!((d0 - global.d_bytes).abs() / global.d_bytes < 1e-9);
    }

    #[test]
    fn identity_parallelism_is_bitwise_identical() {
        let (cluster, mut w, routing) = small_ctx_parts();
        w.backward = true; // exercise the DDP epilogue path too
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let explicit = SchedCtx::new(&cluster, &w, &routing)
            .with_parallelism(ParallelismConfig::identity(cluster.total_gpus()));
        for sys in comparison_set() {
            let a = sys.iteration_time(&ctx);
            let b = sys.iteration_time(&explicit);
            assert_eq!(a.to_bits(), b.to_bits(), "{} diverged under identity config", sys.name());
        }
    }

    #[test]
    fn dp_gradient_ring_emitted_only_when_replicated() {
        let (cluster, mut w, routing) = small_ctx_parts();
        w.backward = true;
        let identity_dag = {
            let ctx = SchedCtx::new(&cluster, &w, &routing);
            ep::VanillaEp.build_iteration(&ctx)
        };
        assert!(
            !identity_dag.tasks.iter().any(|t| t.label == "dp_sync"),
            "identity config must not sync expert replicas"
        );
        let cfg = ParallelismConfig::new(&cluster, 1, 2).unwrap();
        let ctx = SchedCtx::new(&cluster, &w, &routing).with_parallelism(cfg);
        let dag = ep::VanillaEp.build_iteration(&ctx);
        let hops: Vec<_> = dag.tasks.iter().filter(|t| t.label == "dp_sync").collect();
        assert_eq!(hops.len(), cluster.total_gpus(), "one ring hop per GPU position");
        // per-GPU hop: 2·(dp−1)/dp of its n·dp replicated expert payloads
        let shard = (w.experts_per_gpu * 2) as f64 * w.pe_bytes();
        let want = 2.0 * shard * 0.5;
        for t in hops {
            match t.kind {
                crate::netsim::TaskKind::Transfer { bytes, tag, .. } => {
                    assert_eq!(tag, Tag::AllReduce);
                    assert!((bytes - want).abs() < 1e-6, "{bytes} vs {want}");
                }
                _ => panic!("dp_sync must be a transfer"),
            }
        }
    }

    #[test]
    fn expert_compute_conserved_across_systems() {
        // every system must schedule the same total expert compute
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let base = {
            let dag = ep::VanillaEp.build_iteration(&ctx);
            total_expert_compute(&dag)
        };
        assert!(base > 0.0);
        for sys in comparison_set() {
            let dag = sys.build_iteration(&ctx);
            let tot = total_expert_compute(&dag);
            assert!(
                (tot - base).abs() / base < 1e-6,
                "{}: expert compute {tot} != baseline {base}",
                sys.name()
            );
        }
    }
}
