//! MoE training systems as *schedule generators*.
//!
//! Every system consumes the same cluster + workload + routing and emits a
//! [`Dag`] for one training iteration, executed by
//! [`netsim::Simulator`](crate::netsim::Simulator). This mirrors the paper's
//! comparison: identical workloads, different communication/compute schedules.
//!
//! * [`ep::VanillaEp`] — textbook EP: blocking A2A dispatch → expert → A2A
//!   combine (Tutel with pipeline degree 1).
//! * [`ep::Tutel`] — chunked A2A/compute pipelining ([22]).
//! * [`faster_moe::FasterMoe`] — dynamic shadowing of hot experts ([20]).
//! * [`smart_moe::SmartMoe`] — offline expert-placement search ([58]).
//! * [`hybrid_ep::HybridEp`] — this paper: model-guided domain partition +
//!   hierarchical hybrid A2A/AG with parameter-efficient migration.

pub mod aggregate;
pub mod ep;
pub mod faster_moe;
pub mod hybrid_ep;
pub mod smart_moe;

use crate::cluster::ClusterSpec;
use crate::moe::routing::Routing;
use crate::moe::{GpuSpec, MoEWorkload, BYTES_PER_ELEM};
use crate::netsim::{Dag, Simulator, Tag, TaskId};

/// Everything a system needs to build a schedule.
pub struct SchedCtx<'a> {
    pub cluster: &'a ClusterSpec,
    pub workload: &'a MoEWorkload,
    pub gpu: GpuSpec,
    pub routing: &'a Routing,
    /// Fixed per-layer, per-GPU framework time (optimizer step, data
    /// pipeline, non-MoE blocks outside the linear model). Identical for
    /// every system; calibrated against the paper's Table V intercept
    /// (~1.9 s per 12-layer iteration on A800).
    pub fixed_layer_overhead: f64,
}

impl<'a> SchedCtx<'a> {
    pub fn new(cluster: &'a ClusterSpec, workload: &'a MoEWorkload, routing: &'a Routing) -> Self {
        Self { cluster, workload, gpu: GpuSpec::a800(), routing, fixed_layer_overhead: 0.0 }
    }

    pub fn gpus(&self) -> usize {
        self.cluster.total_gpus()
    }

    /// Wire bytes for `tokens` routed tokens.
    pub fn token_bytes(&self, tokens: f64) -> f64 {
        tokens * self.workload.hidden as f64 * BYTES_PER_ELEM
    }

    /// Expert-compute seconds for `tokens` tokens.
    pub fn expert_secs(&self, tokens: f64) -> f64 {
        tokens * self.workload.expert_macs_per_token() / self.gpu.macs_per_sec
    }

    pub fn pre_expert_secs(&self) -> f64 {
        self.workload.lat_pre_expert(&self.gpu) + self.fixed_layer_overhead
    }

    /// Dense (non-expert) parameter bytes per GPU — the DDP All-Reduce
    /// payload the paper treats as a constant (§VI).
    pub fn dense_param_bytes(&self) -> f64 {
        let h = self.workload.hidden as f64;
        let m = self.workload.ffn as f64;
        let blocks = (self.workload.pre_blocks + 1) as f64 * self.workload.moe_layers as f64;
        blocks * (4.0 * h * h + 2.0 * h * m) * BYTES_PER_ELEM
    }
}

/// A system = a named schedule generator.
pub trait System {
    fn name(&self) -> &'static str;

    /// Build one **forward** pass over all MoE layers. `entry[g]` are the
    /// per-GPU entry dependencies; returns per-GPU exit tasks.
    fn build_forward(&self, ctx: &SchedCtx, dag: &mut Dag, entry: &[TaskId]) -> Vec<TaskId>;

    /// Full iteration: forward (+ backward as a mirrored pass with 2× compute
    /// and the same communication volumes, plus the overlappable dense-DDP
    /// All-Reduce — the paper's §VI treatment).
    fn build_iteration(&self, ctx: &SchedCtx) -> Dag {
        let mut dag = Dag::new();
        let g = ctx.gpus();
        let start = dag.barrier(vec![], "iter_start");
        let entry: Vec<TaskId> = (0..g).map(|_| start).collect();
        let fwd_exit = self.build_forward(ctx, &mut dag, &entry);
        if !ctx.workload.backward {
            dag.barrier(fwd_exit, "iter_end");
            return dag;
        }
        // backward: mirrored schedule with doubled compute (dgrad + wgrad)
        let bwd_entry: Vec<TaskId> = fwd_exit
            .iter()
            .enumerate()
            .map(|(gpu, &t)| dag.compute(gpu, 0.0, vec![t], "bwd_entry"))
            .collect();
        let bwd_exit = {
            let doubled = DoubledCompute(self);
            doubled.build_forward(ctx, &mut dag, &bwd_entry)
        };
        // DDP all-reduce of dense params: ring pass, overlapped with backward
        let dense = ctx.dense_param_bytes();
        let ar_bytes = 2.0 * dense * (g as f64 - 1.0) / g as f64;
        let mut ends = bwd_exit.clone();
        for i in 0..g {
            let t = dag.transfer(i, (i + 1) % g, ar_bytes, Tag::AllReduce, vec![bwd_entry[i]], "ddp");
            ends.push(t);
        }
        dag.barrier(ends, "iter_end");
        dag
    }

    /// Simulated iteration time on the given context.
    fn iteration_time(&self, ctx: &SchedCtx) -> f64 {
        let dag = self.build_iteration(ctx);
        Simulator::new(ctx.cluster).run(&dag).makespan
    }
}

/// Wrapper that doubles compute durations (backward ≈ 2× forward FLOPs).
struct DoubledCompute<'s, S: System + ?Sized>(&'s S);

impl<'s, S: System + ?Sized> System for DoubledCompute<'s, S> {
    fn name(&self) -> &'static str {
        "doubled"
    }

    fn build_forward(&self, ctx: &SchedCtx, dag: &mut Dag, entry: &[TaskId]) -> Vec<TaskId> {
        let before = dag.len();
        let out = self.0.build_forward(ctx, dag, entry);
        for t in &mut dag.tasks[before..] {
            if let crate::netsim::TaskKind::Compute { seconds, .. } = &mut t.kind {
                *seconds *= 2.0;
            }
        }
        out
    }
}

/// All registered systems for the comparison tables.
pub fn comparison_set() -> Vec<Box<dyn System>> {
    vec![
        Box::new(ep::Tutel::default()),
        Box::new(faster_moe::FasterMoe::default()),
        Box::new(smart_moe::SmartMoe::default()),
        Box::new(hybrid_ep::HybridEp::default()),
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::presets;
    use crate::netsim::TaskKind;

    pub fn small_ctx_parts() -> (ClusterSpec, MoEWorkload, Routing) {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 512,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 2,
            k: 2,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let routing =
            Routing::uniform(cluster.total_gpus(), cluster.total_gpus() * 2, 512, 2);
        (cluster, w, routing)
    }

    /// Total expert-compute seconds scheduled across all GPUs.
    pub fn total_expert_compute(dag: &Dag) -> f64 {
        dag.tasks
            .iter()
            .filter(|t| t.label.starts_with("expert"))
            .map(|t| match t.kind {
                TaskKind::Compute { seconds, .. } => seconds,
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    #[test]
    fn all_systems_simulate_without_deadlock() {
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        for sys in comparison_set() {
            let t = sys.iteration_time(&ctx);
            assert!(t.is_finite() && t > 0.0, "{} produced {t}", sys.name());
        }
    }

    #[test]
    fn backward_increases_time() {
        let (cluster, mut w, routing) = small_ctx_parts();
        let fwd = {
            let ctx = SchedCtx::new(&cluster, &w, &routing);
            ep::VanillaEp.iteration_time(&ctx)
        };
        w.backward = true;
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let full = ep::VanillaEp.iteration_time(&ctx);
        assert!(full > 1.8 * fwd, "fwd {fwd}, full {full}");
    }

    #[test]
    fn expert_compute_conserved_across_systems() {
        // every system must schedule the same total expert compute
        let (cluster, w, routing) = small_ctx_parts();
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let base = {
            let dag = ep::VanillaEp.build_iteration(&ctx);
            total_expert_compute(&dag)
        };
        assert!(base > 0.0);
        for sys in comparison_set() {
            let dag = sys.build_iteration(&ctx);
            let tot = total_expert_compute(&dag);
            assert!(
                (tot - base).abs() / base < 1e-6,
                "{}: expert compute {tot} != baseline {base}",
                sys.name()
            );
        }
    }
}
