//! SmartMoE-style offline placement search ([58]).
//!
//! SmartMoE combines offline parallelization-plan search with cheap online
//! adjustment. We reproduce the offline part that matters under constrained
//! bandwidth: a greedy expert-placement search that minimizes the
//! bandwidth-weighted A2A cost of the routing histogram, followed by a
//! Tutel-style pipelined schedule using the found placement. Under even
//! routing all placements tie and SmartMoE ≈ Tutel (as in the paper's
//! Table V, where the three baselines are within noise of each other).

use super::ep::plan_pipelined;
use super::{SchedCtx, System};
use crate::moe::routing::Placement;
use crate::plan::Plan;

#[derive(Clone, Copy, Debug)]
pub struct SmartMoe {
    /// Greedy improvement passes over all expert pairs.
    pub passes: usize,
    /// Pipeline degree of the final schedule.
    pub chunks: usize,
}

impl Default for SmartMoe {
    fn default() -> Self {
        Self { passes: 2, chunks: 4 }
    }
}

impl SmartMoe {
    /// Bandwidth-weighted A2A cost of a placement: Σ tokens(i→j) / bw(i, j).
    pub fn placement_cost(ctx: &SchedCtx, placement: &Placement) -> f64 {
        let g = ctx.gpus();
        let mut cost = 0.0;
        for i in 0..g {
            for j in 0..g {
                if i == j {
                    continue;
                }
                let tokens = ctx.routing.tokens_to_gpu(i, j, placement);
                cost += ctx.token_bytes(tokens) / ctx.cluster.bandwidth_between(i, j);
            }
        }
        cost
    }

    /// Greedy pairwise-swap search from the round-robin placement.
    pub fn search_placement(&self, ctx: &SchedCtx) -> Placement {
        let g = ctx.gpus();
        let mut placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
        let total = placement.total_experts();
        let mut cost = Self::placement_cost(ctx, &placement);
        for _ in 0..self.passes {
            let mut improved = false;
            for e1 in 0..total {
                for e2 in e1 + 1..total {
                    if placement.host[e1] == placement.host[e2] {
                        continue;
                    }
                    placement.swap(e1, e2);
                    let c = Self::placement_cost(ctx, &placement);
                    if c + 1e-15 < cost {
                        cost = c;
                        improved = true;
                    } else {
                        placement.swap(e1, e2); // revert
                    }
                }
            }
            if !improved {
                break;
            }
        }
        placement
    }
}

impl System for SmartMoe {
    fn name(&self) -> &'static str {
        "SmartMoE"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let placement = self.search_placement(ctx);
        plan_pipelined(ctx, self.chunks, Some(&placement))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::moe::{MoEWorkload, Routing};

    #[test]
    fn search_never_worsens_cost() {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 1024,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 2,
            k: 2,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        for seed in 0..5u64 {
            let routing = Routing::zipf(8, 16, 1024, 2, 1.2, seed);
            let ctx = SchedCtx::new(&cluster, &w, &routing);
            let base = SmartMoe::placement_cost(
                &ctx,
                &Placement::round_robin(8, w.experts_per_gpu),
            );
            let found = SmartMoe::default().search_placement(&ctx);
            let cost = SmartMoe::placement_cost(&ctx, &found);
            assert!(cost <= base + 1e-12, "seed {seed}: {cost} > {base}");
        }
    }

    #[test]
    fn skew_specific_placement_improves() {
        // concentrate GPU-0 traffic on experts hosted cross-DC: search should
        // bring a hot expert into DC 0
        let cluster = presets::dcs_x_gpus(2, 2, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 1000,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        // GPUs 0,1 (DC0) route everything to expert 3 (hosted on GPU 3, DC1);
        // GPUs 2,3 route everything to expert 0 (GPU 0, DC0).
        let mut tokens = vec![vec![0.0; 4]; 4];
        tokens[0][3] = 1000.0;
        tokens[1][3] = 1000.0;
        tokens[2][0] = 1000.0;
        tokens[3][0] = 1000.0;
        let routing = Routing { tokens };
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let base = SmartMoe::placement_cost(&ctx, &Placement::round_robin(4, 1));
        let found = SmartMoe::default().search_placement(&ctx);
        let cost = SmartMoe::placement_cost(&ctx, &found);
        // swapping experts 0 and 3 removes all cross-DC traffic
        assert!(cost < base * 0.2, "expected big win: {cost} vs {base}");
    }

    #[test]
    fn uniform_routing_is_a_fixed_point() {
        let cluster = presets::cluster_s();
        let w = MoEWorkload::default_paper();
        let routing = Routing::uniform(8, 8, w.tokens_per_gpu, w.k);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let found = SmartMoe::default().search_placement(&ctx);
        assert_eq!(found, Placement::round_robin(8, 1), "uniform: nothing to improve");
    }
}
