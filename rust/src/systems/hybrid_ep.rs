//! HybridEP (this paper): model-guided hybrid expert/data transmission.
//!
//! Per iteration and MoE layer:
//!
//! 1. **Plan** — the stream-model solver picks the expert-domain size per
//!    hierarchy level (`S_ED^l`, §III/§IV-A), unless an explicit partition is
//!    given.
//! 2. **AG expert migration** — every GPU gathers the experts of its domain
//!    peers, innermost level first (hierarchical AG); with
//!    *parameter-efficient migration* the payload is the SR-compressed
//!    residual (`P_E / CR`), SREncode is fused with the previous optimizer
//!    step and SRDecode with expert compute (§IV-B). AG overlaps pre-expert
//!    compute (the asynchronous communicator, Fig. 10).
//! 3. **A2A data routing** — tokens whose expert lives outside the local
//!    expert group hop toward the owning domain, outermost level first
//!    (hierarchical A2A à la Algorithm 1: each hop goes to the same-offset
//!    mirror in the destination domain).
//! 4. **Expert compute** — each GPU computes *all* experts it now holds on
//!    every token that reached it.
//! 5. **Combine** — results retrace the dispatch path in reverse.
//!
//! With `S_ED = 1` everywhere this degenerates to (hierarchical) EP — EP is a
//! special case of HybridEP (§III-E).
//!
//! Every AG/dispatch phase carries the default
//! [`crate::plan::Sync::Bulk`] barrier policy — the hierarchical hops are
//! phase-synchronised by construction (Algorithm 1) — and phases with no
//! flows are filtered out before they reach the IR, so lowering never sees
//! empty `CommPhase`s.

use super::{SchedCtx, System};
use crate::cluster::Multilevel;
use crate::model::solver::plan_multilevel;
use crate::moe::routing::{Placement, Routing};
use crate::plan::{CommPhase, Flow, LayerPlan, MigratePlan, Plan, Round};
use crate::topology::DomainPartition;

/// Parameter-efficient migration settings (§IV-B).
#[derive(Clone, Copy, Debug)]
pub struct MigrationCfg {
    /// SR compression ratio `CR` (wire bytes = `P_E / CR`). Paper uses 50×.
    pub compression_ratio: f64,
    /// SREncode/SRDecode throughput over the *full* expert bytes.
    pub codec_bytes_per_sec: f64,
    /// Fuse SREncode with the optimizer step (−30%) and SRDecode with expert
    /// compute (−45%) — Fig. 15.
    pub fused: bool,
}

impl Default for MigrationCfg {
    fn default() -> Self {
        // codec throughput is memory-bound on the accelerator; 100 GB/s is a
        // conservative A800-class estimate (HBM ≈ 2 TB/s), calibrated against
        // the Fig. 15 measurements of the Rust codec scaled to GPU bandwidth.
        Self { compression_ratio: 50.0, codec_bytes_per_sec: 100e9, fused: true }
    }
}

impl MigrationCfg {
    pub fn encode_secs(&self, pe_bytes: f64) -> f64 {
        pe_bytes / self.codec_bytes_per_sec * if self.fused { 0.70 } else { 1.0 }
    }

    pub fn decode_secs(&self, pe_bytes: f64) -> f64 {
        pe_bytes / self.codec_bytes_per_sec * if self.fused { 0.55 } else { 1.0 }
    }
}

/// The HybridEP scheduler.
#[derive(Clone, Debug, Default)]
pub struct HybridEp {
    /// Explicit `S_ED` per level; `None` = solve with the stream model.
    pub partition: Option<Vec<usize>>,
    /// Parameter-efficient migration; `None` = migrate raw experts
    /// (domain-based partition only — the Table VI "Partition" baseline).
    pub migration: Option<MigrationCfg>,
}

impl HybridEp {
    pub fn with_migration() -> Self {
        Self { partition: None, migration: Some(MigrationCfg::default()) }
    }

    pub fn partition_only() -> Self {
        Self { partition: None, migration: None }
    }

    /// Expert bytes as transmitted.
    pub fn pe_tx_bytes(&self, ctx: &SchedCtx) -> f64 {
        let pe = ctx.workload.pe_bytes();
        match &self.migration {
            Some(m) => pe / m.compression_ratio,
            None => pe,
        }
    }

    /// Resolve the domain partition for the first layer (solve unless
    /// explicit) — the single-partition view callers use when no per-layer
    /// trace is in play.
    pub fn resolve_partition(&self, ctx: &SchedCtx) -> DomainPartition {
        self.resolve_partition_for_layer(ctx, 0)
    }

    /// Resolve the domain partition for one layer. With an explicit
    /// `partition` every layer gets it; otherwise the stream-model solver
    /// runs on the layer's own routing (per-layer `p_l`): skewed layers see
    /// a larger effective `D` and solve to bigger expert domains.
    pub fn resolve_partition_for_layer(&self, ctx: &SchedCtx, layer: usize) -> DomainPartition {
        let ml = ctx.cluster.multilevel();
        match &self.partition {
            Some(sizes) => DomainPartition::new(&ml, sizes.clone())
                .expect("explicit partition incompatible with cluster"),
            None => {
                let input = ctx.plan_input_for_layer(layer, self.pe_tx_bytes(ctx));
                let plan = plan_multilevel(ctx.cluster, &input).expect("planner failed");
                plan.partition(&ml).expect("planner produced invalid partition")
            }
        }
    }
}

/// Coordinate-wise domain id of `loc` at `level` under partition `part`.
fn domain_coord(part: &DomainPartition, loc: &[usize], level: usize) -> usize {
    loc[level] / part.size_at(level)
}

/// Outermost level at which `m`'s and `h`'s domain coordinates differ
/// (`None` = same expert group: no data movement needed).
fn diverge_level(
    ml: &Multilevel,
    part: &DomainPartition,
    loc_m: &[usize],
    loc_h: &[usize],
) -> Option<usize> {
    (0..ml.levels()).find(|&l| domain_coord(part, loc_m, l) != domain_coord(part, loc_h, l))
}

/// The same-offset mirror of `m` in `h`'s domain at `level` (next A2A hop).
fn next_hop(
    ml: &Multilevel,
    part: &DomainPartition,
    loc_m: &[usize],
    loc_h: &[usize],
    level: usize,
) -> usize {
    let s = part.size_at(level);
    let mut loc = loc_m.to_vec();
    loc[level] = domain_coord(part, loc_h, level) * s + (loc_m[level] % s);
    ml.index_of(&loc)
}

/// Movement derived from one layer's partition + routing: hierarchical AG
/// phases (innermost level first) and hierarchical A2A dispatch phases
/// (outermost level first), plus the resulting per-GPU steady state.
struct LayerMovement {
    /// per AG phase: (src, dst, #source-GPUs' experts moved)
    ag_flows: Vec<Vec<(usize, usize, usize)>>,
    /// holdings[m] = #source GPUs whose experts m holds after AG
    holdings: Vec<usize>,
    /// per dispatch phase: (src, dst, tokens)
    disp_flows: Vec<Vec<(usize, usize, f64)>>,
    /// tokens computed at each GPU after all hops
    compute_tokens: Vec<f64>,
}

fn layer_movement(
    ml: &Multilevel,
    part: &DomainPartition,
    placement: &Placement,
    routing: &Routing,
    locs: &[Vec<usize>],
) -> LayerMovement {
    let g = ml.total_gpus();
    let nlevels = ml.levels();

    // AG: innermost level first
    let mut holdings: Vec<usize> = vec![1; g];
    let mut ag_flows: Vec<Vec<(usize, usize, usize)>> = Vec::new();
    for l in (0..nlevels).rev() {
        let s = part.size_at(l);
        if s <= 1 {
            ag_flows.push(Vec::new());
            continue;
        }
        let mut phase = Vec::new();
        let mut new_holdings = holdings.clone();
        for m in 0..g {
            // AG peers at level l: same domain, different offset, same other coords
            let dom = domain_coord(part, &locs[m], l);
            let off = locs[m][l] % s;
            for o in 0..s {
                if o == off {
                    continue;
                }
                let mut loc = locs[m].clone();
                loc[l] = dom * s + o;
                let peer = ml.index_of(&loc);
                phase.push((peer, m, holdings[peer]));
                new_holdings[m] += holdings[peer];
            }
        }
        holdings = new_holdings;
        ag_flows.push(phase);
    }

    // A2A: token bookkeeping. hold[m][e] = tokens at m destined for expert e
    let total_experts = placement.total_experts();
    let mut hold: Vec<Vec<f64>> = (0..g).map(|m| routing.tokens[m].clone()).collect();
    let mut disp_flows: Vec<Vec<(usize, usize, f64)>> = Vec::new();
    for l in 0..nlevels {
        let mut phase: Vec<(usize, usize, f64)> = Vec::new();
        let mut moves: Vec<(usize, usize, usize, f64)> = Vec::new(); // (src,dst,expert,tokens)
        for m in 0..g {
            for e in 0..total_experts {
                let t = hold[m][e];
                if t <= 0.0 {
                    continue;
                }
                let h = placement.host[e];
                if diverge_level(ml, part, &locs[m], &locs[h]) == Some(l) {
                    let j = next_hop(ml, part, &locs[m], &locs[h], l);
                    moves.push((m, j, e, t));
                }
            }
        }
        let mut agg: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for &(m, j, e, t) in &moves {
            hold[m][e] -= t;
            hold[j][e] += t;
            *agg.entry((m, j)).or_default() += t;
        }
        phase.extend(agg.into_iter().map(|((m, j), t)| (m, j, t)));
        disp_flows.push(phase);
    }
    let compute_tokens: Vec<f64> = hold.iter().map(|h| h.iter().sum()).collect();

    LayerMovement { ag_flows, holdings, disp_flows, compute_tokens }
}

impl System for HybridEp {
    fn name(&self) -> &'static str {
        "HybridEP"
    }

    fn plan_forward(&self, ctx: &SchedCtx) -> Plan {
        let g = ctx.gpus();
        let ml = ctx.cluster.multilevel();
        let placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
        let locs: Vec<Vec<usize>> = (0..g).map(|m| ml.locate(m)).collect();
        let pe_tx = self.pe_tx_bytes(ctx);
        let pe_full = ctx.workload.pe_bytes();
        let n_exp = ctx.workload.experts_per_gpu;
        let mig = self.migration.as_ref();

        let mut layers = Vec::new();
        // without a per-layer trace every layer solves to the same
        // partition: resolve once (the pre-refactor fast path)
        let static_part = if ctx.layer_routing.is_none() {
            Some(self.resolve_partition_for_layer(ctx, 0))
        } else {
            None
        };
        // movement cache: layers with the same partition and no per-layer
        // trace share one movement plan
        let mut cache: Option<(DomainPartition, LayerMovement)> = None;
        for layer in 0..ctx.workload.moe_layers {
            let part = match &static_part {
                Some(p) => p.clone(),
                None => self.resolve_partition_for_layer(ctx, layer),
            };
            let reuse = ctx.layer_routing.is_none()
                && cache.as_ref().map_or(false, |(p, _)| *p == part);
            if !reuse {
                let mv = layer_movement(&ml, &part, &placement, ctx.routing_for(layer), &locs);
                cache = Some((part, mv));
            }
            let mv = &cache.as_ref().unwrap().1;

            // SREncode (fused with last optimizer step when `fused`) feeds
            // the hierarchical AG, which overlaps pre-expert compute
            let migrate = MigratePlan {
                prologue_secs: mig.map(|c| vec![c.encode_secs(pe_full) * n_exp as f64; g]),
                prologue_label: "sr_encode",
                phases: mv
                    .ag_flows
                    .iter()
                    .filter(|p| !p.is_empty())
                    .map(|phase| {
                        CommPhase::new(
                            phase
                                .iter()
                                .map(|&(src, dst, nsrc)| Flow {
                                    src,
                                    dst,
                                    bytes: nsrc as f64 * n_exp as f64 * pe_tx,
                                })
                                .collect(),
                            "ag",
                        )
                    })
                    .collect(),
            };

            // expert compute (+ fused SRDecode of gathered experts)
            let expert_secs: Vec<f64> = (0..g)
                .map(|m| {
                    let mut secs = ctx.expert_secs(mv.compute_tokens[m]);
                    if let Some(c) = mig {
                        let gathered = (mv.holdings[m] - 1) as f64 * n_exp as f64;
                        secs += gathered * c.decode_secs(pe_full);
                    }
                    secs
                })
                .collect();

            // hierarchical A2A dispatch (phase-synchronized per GPU)
            let dispatch: Vec<CommPhase> = mv
                .disp_flows
                .iter()
                .filter(|p| !p.is_empty())
                .map(|phase| {
                    CommPhase::new(
                        phase
                            .iter()
                            .map(|&(src, dst, tokens)| Flow {
                                src,
                                dst,
                                bytes: ctx.token_bytes(tokens),
                            })
                            .collect(),
                        "dispatch",
                    )
                })
                .collect();

            layers.push(LayerPlan {
                migrate,
                pre_secs: vec![ctx.pre_expert_secs(); g],
                rounds: vec![Round { dispatch, expert_secs }],
                tp_sync: None,
            });
        }
        Plan { gpus: g, layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::moe::{MoEWorkload, Routing};
    use crate::netsim::{Simulator, Tag};
    use crate::systems::ep::{Tutel, VanillaEp};
    use crate::systems::testutil::total_expert_compute;

    fn parts(
        tokens: usize,
        ffn: usize,
    ) -> (crate::cluster::ClusterSpec, MoEWorkload, Routing) {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: tokens,
            hidden: 512,
            ffn,
            experts_per_gpu: 1,
            k: 2,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let routing = Routing::uniform(8, 8, tokens, 2);
        (cluster, w, routing)
    }

    #[test]
    fn beats_ep_when_data_dominates() {
        // big data, small experts → AG-only should crush EP
        let (cluster, w, routing) = parts(16384, 128);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let ep = VanillaEp.iteration_time(&ctx);
        let tutel = Tutel::default().iteration_time(&ctx);
        let hy = HybridEp::with_migration().iteration_time(&ctx);
        assert!(hy < tutel && hy < ep, "hybrid {hy} vs tutel {tutel} / ep {ep}");
        assert!(ep / hy > 2.0, "expected ≥2× win, got {:.2}×", ep / hy);
    }

    #[test]
    fn degenerates_to_ep_with_unit_domains() {
        let (cluster, w, routing) = parts(512, 512);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let hy = HybridEp { partition: Some(vec![1, 1]), migration: None };
        let dag = hy.build_iteration(&ctx);
        // no AG traffic at all
        assert_eq!(dag.traffic_by_tag(Tag::AG), 0.0);
        // hierarchical A2A still moves all remote tokens (relayed)
        assert!(dag.traffic_by_tag(Tag::A2A) > 0.0);
    }

    #[test]
    fn full_domains_have_no_a2a() {
        let (cluster, w, routing) = parts(512, 512);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let hy = HybridEp { partition: Some(vec![2, 4]), migration: None };
        let dag = hy.build_iteration(&ctx);
        assert_eq!(dag.traffic_by_tag(Tag::A2A), 0.0, "every expert is local after AG");
        assert!(dag.traffic_by_tag(Tag::AG) > 0.0);
    }

    #[test]
    fn expert_compute_conserved() {
        let (cluster, w, routing) = parts(1024, 512);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let base = total_expert_compute(&VanillaEp.build_iteration(&ctx));
        for partition in [vec![1, 1], vec![1, 2], vec![1, 4], vec![2, 1], vec![2, 4]] {
            let hy = HybridEp { partition: Some(partition.clone()), migration: None };
            let got = total_expert_compute(&hy.build_iteration(&ctx));
            assert!(
                (got - base).abs() / base < 1e-9,
                "partition {partition:?}: {got} != {base}"
            );
        }
    }

    #[test]
    fn compression_shrinks_ag_traffic() {
        let (cluster, w, routing) = parts(512, 2048);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let part = Some(vec![2usize, 4]);
        let raw = HybridEp { partition: part.clone(), migration: None };
        let mig = HybridEp {
            partition: part,
            migration: Some(MigrationCfg { compression_ratio: 50.0, ..Default::default() }),
        };
        let t_raw = raw.build_iteration(&ctx).traffic_by_tag(Tag::AG);
        let t_mig = mig.build_iteration(&ctx).traffic_by_tag(Tag::AG);
        assert!((t_raw / t_mig - 50.0).abs() < 1e-6, "CR not applied: {t_raw} / {t_mig}");
    }

    #[test]
    fn solver_driven_partition_is_sane() {
        let (cluster, w, routing) = parts(4096, 256);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let hy = HybridEp::with_migration();
        let part = hy.resolve_partition(&ctx);
        // cheap compressed experts + heavy data → large domains expected
        assert!(part.sizes().iter().product::<usize>() > 1, "solver chose pure EP: {part:?}");
        let t = hy.iteration_time(&ctx);
        assert!(t.is_finite() && t > 0.0);
    }

    #[test]
    fn hierarchical_relay_reaches_every_expert() {
        // skewed routing on a 2-level cluster: every token must be computed
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 100,
            hidden: 64,
            ffn: 64,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let routing = Routing::zipf(8, 8, 100, 1, 1.4, 11);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        for partition in [vec![1, 2], vec![2, 2], vec![1, 4]] {
            let hy = HybridEp { partition: Some(partition.clone()), migration: None };
            let dag = hy.build_iteration(&ctx);
            let got = total_expert_compute(&dag);
            let want = ctx.expert_secs(800.0); // 8 GPUs × 100 tokens × K=1
            assert!(
                (got - want).abs() / want < 1e-9,
                "partition {partition:?} lost tokens: {got} vs {want}"
            );
            // and the schedule executes
            let r = Simulator::new(&cluster).run(&dag);
            assert!(r.makespan.is_finite());
        }
    }
}
