//! Stream-based modeling (HybridEP §III).
//!
//! MoE training is decoupled into a **computation stream** (Eq. 1–2) and a
//! **communication stream** (Eq. 3–5); their **overlap** (Eq. 6–7) joins them
//! into the end-to-end latency (Eq. 8). The solver ([`solver`]) minimizes the
//! final latency over the proportion `p` of data chunks kept on A2A
//! (Eq. 9–12, Fig. 6).
//!
//! Notation (Table I): `D` data bytes per GPU, `P_E` expert bytes, `C`
//! computation throughput, `B` bandwidth, `G` GPUs, `n` experts per GPU.

pub mod solver;

/// Latency of one GeMM of shape `(l, h) × (h, m)` — Eq. 1: `L·M·H / C`.
///
/// `c` is the effective throughput in multiply-accumulate/s (the paper's
/// linear model; the factor 2 for FLOPs is absorbed into `C`).
pub fn gemm_latency(l: usize, h: usize, m: usize, c: f64) -> f64 {
    (l as f64) * (m as f64) * (h as f64) / c
}

/// Stream-model inputs for one homogeneous GPU group (one level).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// Number of GPUs `G` in the group (> 1 for anything to transmit).
    pub g: usize,
    /// Data bytes `D` leaving one GPU per MoE layer.
    pub d_bytes: f64,
    /// Bytes of one expert `P_E` *as transmitted* (post-compression when
    /// parameter-efficient migration is on).
    pub pe_bytes: f64,
    /// Experts per GPU `n`.
    pub n_experts: usize,
    /// Bandwidth `B`, bytes/s.
    pub bandwidth: f64,
    /// Pre-expert computation latency `Lat_comp^PE` (Eq. 2).
    pub lat_pe: f64,
    /// Per-expert computation latency `Lat_comp^Ep`.
    pub lat_ep: f64,
}

impl StreamConfig {
    /// A2A traffic for proportion `p` — Eq. 3 scaled by `p` (Def. 1):
    /// `V^A2A(p) = p · D · (G−1)/G`.
    pub fn v_a2a(&self, p: f64) -> f64 {
        p * self.d_bytes * (self.g as f64 - 1.0) / self.g as f64
    }

    /// AG traffic for proportion `p` — Eq. 4: the `(1−p)` share of the `G−1`
    /// remote chunks is covered by migrating experts instead:
    /// `V^AG(p) = (1−p) · (G−1) · P_E · n`.
    pub fn v_ag(&self, p: f64) -> f64 {
        (1.0 - p) * (self.g as f64 - 1.0) * self.pe_bytes * self.n_experts as f64
    }

    pub fn lat_a2a(&self, p: f64) -> f64 {
        self.v_a2a(p) / self.bandwidth
    }

    pub fn lat_ag(&self, p: f64) -> f64 {
        self.v_ag(p) / self.bandwidth
    }

    /// Computation stream — Eq. 2: `Lat_comp = Lat^PE + n · Lat^Ep`.
    pub fn lat_comp(&self) -> f64 {
        self.lat_pe + self.n_experts as f64 * self.lat_ep
    }

    /// Communication stream — Eq. 5: `Lat^AG + 2·Lat^A2A` (A2A runs before
    /// and after expert computation; AG runs once — experts are not sent
    /// back).
    pub fn lat_comm(&self, p: f64) -> f64 {
        self.lat_ag(p) + 2.0 * self.lat_a2a(p)
    }

    /// Overlap — Eq. 7: expert computation fully overlaps with AG/A2A
    /// (pipelined, per [35], [46]); pre-expert computation overlaps with AG
    /// up to `min(Lat^PE, Lat^AG)`. A2A cannot overlap pre-expert compute
    /// (data dependency).
    pub fn lat_ovlp(&self, p: f64) -> f64 {
        self.lat_pe.min(self.lat_ag(p)) + self.n_experts as f64 * self.lat_ep
    }

    /// End-to-end latency — Eq. 8, which simplifies to
    /// `max(Lat^PE, Lat^AG(p)) + 2·Lat^A2A(p)` (see `solver` docs).
    pub fn lat_final(&self, p: f64) -> f64 {
        self.lat_comp() + self.lat_comm(p) - self.lat_ovlp(p)
    }

    /// The paper's Case-2 discriminant `2D − G·P_E·n` (Fig. 6): negative →
    /// a mixed optimum exists (Case 2.1); non-negative → AG-only (Case 2.2).
    pub fn case2_discriminant(&self) -> f64 {
        2.0 * self.d_bytes - self.g as f64 * self.pe_bytes * self.n_experts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit;

    fn cfg() -> StreamConfig {
        StreamConfig {
            g: 8,
            d_bytes: 8e6,
            pe_bytes: 4.7e6,
            n_experts: 1,
            bandwidth: 128.0e9 / 8.0,
            lat_pe: 0.049e-3,
            lat_ep: 0.02e-3,
        }
    }

    #[test]
    fn gemm_eq1() {
        assert_eq!(gemm_latency(2, 3, 4, 1.0), 24.0);
        assert_eq!(gemm_latency(100, 100, 100, 1e6), 1.0);
    }

    #[test]
    fn traffic_extremes() {
        let c = cfg();
        // p = 1: pure EP — Eq. 3 exactly, no AG
        assert!((c.v_a2a(1.0) - 8e6 * 7.0 / 8.0).abs() < 1.0);
        assert_eq!(c.v_ag(1.0), 0.0);
        // p = 0: AG only — Eq. 4 exactly, no A2A
        assert_eq!(c.v_a2a(0.0), 0.0);
        assert!((c.v_ag(0.0) - 7.0 * 4.7e6).abs() < 1.0);
    }

    #[test]
    fn traffic_exchange_rate() {
        // §III-B: when A2A traffic decreases by D/G, AG increases by P_E.
        let c = cfg();
        let dp = 1.0 / (c.g as f64 - 1.0); // one chunk
        let da2a = c.v_a2a(1.0) - c.v_a2a(1.0 - dp);
        let dag = c.v_ag(1.0 - dp) - c.v_ag(1.0);
        assert!((da2a - c.d_bytes / c.g as f64).abs() < 1.0, "ΔA2A = {da2a}");
        assert!((dag - c.pe_bytes).abs() < 1.0, "ΔAG = {dag}");
    }

    #[test]
    fn final_latency_closed_form() {
        // Lat_final(p) == max(lat_pe, lat_ag) + 2·lat_a2a for all p
        testkit::check("latfinal-closed-form", 100, |g| {
            let c = StreamConfig {
                g: g.usize_in(2, 64),
                d_bytes: g.rng.f64() * 1e8 + 1.0,
                pe_bytes: g.rng.f64() * 1e7 + 1.0,
                n_experts: g.usize_in(1, 8),
                bandwidth: g.rng.f64() * 1e10 + 1e6,
                lat_pe: g.rng.f64() * 1e-2,
                lat_ep: g.rng.f64() * 1e-3,
            };
            let p = g.rng.f64();
            let direct = c.lat_final(p);
            let closed = c.lat_pe.max(c.lat_ag(p)) + 2.0 * c.lat_a2a(p);
            prop_assert!(
                testkit::close(direct, closed, 1e-9),
                "direct {direct} != closed {closed} at p={p}"
            );
            Ok(())
        });
    }

    #[test]
    fn ep_is_special_case() {
        // p = 1 (pure EP): no AG; latency = lat_pe + 2·A2A latency
        let c = cfg();
        let want = c.lat_pe + 2.0 * c.lat_a2a(1.0);
        assert!((c.lat_final(1.0) - want).abs() < 1e-12);
    }
}
