//! Optimal-proportion solver (HybridEP §III-D/E, Fig. 6) and the multilevel
//! planner that turns model output into a [`DomainPartition`].
//!
//! ## Derivation recap
//!
//! Substituting Eq. 2/5/7 into Eq. 8:
//!
//! ```text
//! Lat_final(p) = Lat_comp + Lat_comm − Lat_ovlp
//!              = Lat^PE + n·Lat^Ep + Lat^AG + 2·Lat^A2A − min(Lat^PE, Lat^AG) − n·Lat^Ep
//!              = max(Lat^PE, Lat^AG(p)) + 2·Lat^A2A(p)
//! ```
//!
//! * **Case 1** (`Lat^PE ≥ Lat^AG`): latency grows linearly in `p`
//!   (Eq. 11) — take the smallest feasible `p`, i.e. the boundary
//!   `p_c = 1 − B·Lat^PE / (n·P_E·(G−1))`.
//! * **Case 2** (`Lat^PE < Lat^AG`): slope is `(G−1)(2D − G·n·P_E)/(GB)`
//!   (Eq. 12). If `2D − G·n·P_E < 0` (Case 2.1) latency falls with `p` →
//!   optimum at the case boundary `p_c`; otherwise (Case 2.2) it rises →
//!   optimum at `p = 0` (AG-only).
//!
//! When `p = 1` HybridEP degenerates into standard EP — EP is a special case.
//!
//! ## Grid solver
//!
//! §V-B maps candidates to expert-domain sizes via `p = 1 − S_ED/G`
//! (`S_ED = 1 ⇒ p = 1`); the *deployable* optimum is the argmin of
//! `Lat_final` over divisors of `G` (the paper's candidate set). We solve the
//! continuous optimum for reporting and the grid optimum for scheduling.
//!
//! ## Joint PP × TP × EP × DP solver
//!
//! [`solve_joint`] generalizes the grid beyond the paper: every deployable
//! `(pp, tp, dp)` factorization of the cluster (hybrid tensor-expert-data
//! parallelism à la DeepSpeed-TED plus stage-partitioned pipeline MoE,
//! PAPERS.md) re-solves the per-level `p` optimum on its virtual cluster and
//! adds the TP activation-All-Reduce and DP expert-gradient-ring terms,
//! making the parallelism layout itself a planned dimension. Pipeline
//! candidates (`pp > 1`) carve the MoE layers into `pp` contiguous stage
//! blocks, tune the microbatch count, and pay an explicit **bubble tax** —
//! `(M + pp − 1)` slots of per-microbatch stage work instead of `M` — plus
//! the exposed stage-boundary activation hops of the pipeline fill.
//! [`solve_joint_simulated`] scores the same grid by
//! **full simulated iterations** instead of the stream model — with one
//! simulation per *distinct resolved deployment*: grid `p` values snap to
//! divisor partitions, so distinct points frequently alias, and the memo
//! ([`JointSimStats`]) collapses the duplicates.

use anyhow::{ensure, Result};

use super::StreamConfig;
use crate::cluster::{ClusterSpec, Multilevel, ParallelismConfig};
use crate::moe::{GpuSpec, MoEWorkload};
use crate::topology::DomainPartition;

/// Which analytical regime produced the optimum (Fig. 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveCase {
    /// `2D − G·n·P_E < 0`: mixed A2A+AG optimum at the case boundary.
    Mixed,
    /// `2D − G·n·P_E ≥ 0`: AG-only (`p = 0`).
    AgOnly,
}

#[derive(Clone, Copy, Debug)]
pub struct Solution {
    /// Continuous optimal proportion `p* ∈ [0, 1]`.
    pub p_star: f64,
    pub case: SolveCase,
    /// Predicted latency at `p*`.
    pub latency: f64,
}

/// Closed-form continuous optimum (Eq. 10–12 + Fig. 6 summary).
pub fn solve_continuous(c: &StreamConfig) -> Solution {
    let case = if c.case2_discriminant() < 0.0 { SolveCase::Mixed } else { SolveCase::AgOnly };
    let p_star = match case {
        SolveCase::AgOnly => 0.0,
        SolveCase::Mixed => {
            // boundary where Lat^AG(p) == Lat^PE
            let denom = c.pe_bytes * c.n_experts as f64 * (c.g as f64 - 1.0);
            (1.0 - c.bandwidth * c.lat_pe / denom).clamp(0.0, 1.0)
        }
    };
    Solution { p_star, case, latency: c.lat_final(p_star) }
}

/// One grid candidate: a deployable expert-domain size and its cost.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub s_ed: usize,
    pub p: f64,
    pub latency: f64,
}

/// §V-B candidate mapping: `p(S_ED) = 1 − S_ED/G`, with `S_ED = 1 ⇒ p = 1`.
pub fn p_of_domain(g: usize, s_ed: usize) -> f64 {
    if s_ed <= 1 {
        1.0
    } else {
        1.0 - s_ed as f64 / g as f64
    }
}

/// All divisors of `g` as candidate domain sizes, with predicted latencies.
pub fn grid_candidates(c: &StreamConfig) -> Vec<Candidate> {
    (1..=c.g)
        .filter(|s| c.g % s == 0)
        .map(|s_ed| {
            let p = p_of_domain(c.g, s_ed);
            Candidate { s_ed, p, latency: c.lat_final(p) }
        })
        .collect()
}

/// Deployable optimum: argmin latency over the divisor grid; ties prefer the
/// larger domain (less A2A frequency — Table VII).
pub fn solve_grid(c: &StreamConfig) -> Candidate {
    grid_candidates(c)
        .into_iter()
        .max_by(|a, b| {
            // min latency, tie → larger s_ed: compare reversed latency, then s_ed
            b.latency.partial_cmp(&a.latency).unwrap().then(a.s_ed.cmp(&b.s_ed))
        })
        .expect("g >= 1 yields at least one candidate")
}

/// Workload view the planner needs (derived from a `moe::MoEWorkload`).
#[derive(Clone, Copy, Debug)]
pub struct PlanInput {
    /// Data bytes leaving one GPU per MoE layer (`D`).
    pub d_bytes: f64,
    /// Transmitted expert size (`P_E`, post-compression).
    pub pe_bytes: f64,
    /// Experts per GPU (`n`).
    pub n_experts: usize,
    /// Pre-expert computation latency per layer.
    pub lat_pe: f64,
    /// Per-expert computation latency.
    pub lat_ep: f64,
}

/// Plan for one level of the hierarchy.
#[derive(Clone, Debug)]
pub struct LevelPlan {
    pub level: usize,
    pub s_ed: usize,
    pub p: f64,
    pub latency: f64,
    pub case: SolveCase,
}

/// The full multilevel plan: a domain size per level (the thing
/// `DomainPartition` consumes) plus the analytical predictions.
#[derive(Clone, Debug)]
pub struct Plan {
    pub levels: Vec<LevelPlan>,
    pub partition_sizes: Vec<usize>,
    /// Predicted per-layer iteration latency (sum of the bottleneck level
    /// costs; inner levels overlap under the hierarchical schedule).
    pub predicted_latency: f64,
}

/// Multilevel planner: solve each level of the hierarchy independently
/// (outermost first), consuming the pre-expert overlap budget as AG time is
/// committed at outer levels.
///
/// At level `l` the mirrors a GPU talks to are the `SF^l − 1` sibling
/// workers; the data crossing that level per GPU is
/// `D_l = D / Π_{j<l} SF^j` (hierarchical A2A aggregates inner subtrees),
/// while expert migration is always whole experts (`n · P_E`).
pub fn plan_multilevel(cluster: &ClusterSpec, w: &PlanInput) -> Result<Plan> {
    let ml = cluster.multilevel();
    let mut levels = Vec::new();
    let mut sizes = Vec::new();
    let mut pe_budget = w.lat_pe;
    let mut total = 0.0;
    for (l, spec) in cluster.levels.iter().enumerate() {
        let outer_product: usize = ml.scaling()[..l].iter().product();
        let cfg = StreamConfig {
            g: spec.fanout,
            d_bytes: w.d_bytes / outer_product as f64,
            pe_bytes: w.pe_bytes,
            n_experts: w.n_experts,
            // heterogeneous links: plan against the slowest sibling uplink
            // (the straggler paces every synchronized collective phase)
            bandwidth: cluster.min_bandwidth_at(l),
            lat_pe: pe_budget,
            lat_ep: w.lat_ep,
        };
        let best = if spec.fanout == 1 {
            Candidate { s_ed: 1, p: 1.0, latency: 0.0 }
        } else {
            solve_grid(&cfg)
        };
        let case =
            if cfg.case2_discriminant() < 0.0 { SolveCase::Mixed } else { SolveCase::AgOnly };
        // the AG time committed at this level eats into the overlap budget
        pe_budget = (pe_budget - cfg.lat_ag(best.p)).max(0.0);
        total += best.latency;
        levels.push(LevelPlan { level: l, s_ed: best.s_ed, p: best.p, latency: best.latency, case });
        sizes.push(best.s_ed);
    }
    Ok(Plan { levels, partition_sizes: sizes, predicted_latency: total })
}

impl Plan {
    pub fn partition(&self, ml: &Multilevel) -> Result<DomainPartition> {
        DomainPartition::new(ml, self.partition_sizes.clone())
    }
}

/// Per-layer planning: one [`Plan`] per MoE layer, each solved on that
/// layer's own [`PlanInput`] (routing skew rescales the effective `D` —
/// see `SchedCtx::plan_input_for_layer`). The resulting `p_l` profile is
/// pointwise optimal, so its predicted total latency is never worse than
/// holding any single partition across all layers.
pub fn plan_layers(cluster: &ClusterSpec, inputs: &[PlanInput]) -> Result<Vec<Plan>> {
    inputs.iter().map(|w| plan_multilevel(cluster, w)).collect()
}

// ---------------------------------------------------------------------------
// Joint PP × TP × EP × DP planning (hybrid tensor-expert-data parallelism à
// la DeepSpeed-TED — Singh et al., PAPERS.md — plus stage-partitioned
// pipeline MoE with microbatch interleaving)
// ---------------------------------------------------------------------------

/// Microbatch counts the pipeline candidates tune over (`pp > 1` only;
/// counts that do not divide the stage's token supply are skipped).
pub const MICROBATCH_GRID: &[usize] = &[1, 2, 4, 8];

/// One joint-parallelism candidate: a deployable `(pp, tp, ep, dp)`
/// factorization of the cluster plus the hybrid-proportion plan solved on
/// its [virtual cluster](ParallelismConfig::virtual_cluster). The search is
/// therefore over the full `(p, pp, M, tp, dp)` grid: each point re-solves
/// the per-level `p` optimum under its own geometry.
#[derive(Clone, Debug)]
pub struct JointCandidate {
    pub config: ParallelismConfig,
    /// Multilevel hybrid plan on the candidate's virtual cluster (partition
    /// sizes are per *virtual* level — hand them to `HybridEp.partition`
    /// together with the config). For `pp > 1` the virtual cluster is the
    /// stage's: the plan prices one microbatch through one stage layer.
    pub plan: Plan,
    /// Per-MoE-layer forward cost: stream-model latency plus the TP
    /// activation-All-Reduce tax (`2·(tp−1)·(m+1)·D / B_inner`). For
    /// `pp > 1` this is per *microbatch* stage layer (tokens scaled
    /// `pp/M`).
    pub layer_latency: f64,
    /// Per-iteration ranking score: comm passes × layers × `layer_latency`,
    /// plus the expert-replica gradient ring (`2·(dp−1)·n·P_E / B_outer`)
    /// when `dp > 1` — replicated experts must be kept coherent once per
    /// iteration whether or not the simulated DAG carries a backward pass.
    /// Pipeline candidates instead pay `(M + pp − 1)` slots of stage work
    /// (the 1F1B bubble tax) plus the exposed fill-time boundary hops.
    pub score: f64,
}

/// Score every deployable `(pp, M, tp, dp)` factorization: `tp` over
/// divisors of the innermost fanout, `pp` and `dp` over divisors of the
/// outermost (`pp` additionally restricted to divisors of the MoE layer
/// count, `M` to [`MICROBATCH_GRID`] counts that divide the stage's token
/// supply), all jointly dividing `G`. Volumes are *member-view*
/// ([`member_plan_input`](crate::plan::parallel::member_plan_input)), so
/// the identity candidate reproduces [`plan_multilevel`] on the physical
/// cluster exactly.
///
/// Candidates come back **sorted best-first** (minimal score; ties prefer
/// fewer parallel degrees) — [`solve_joint`] is the head of this list.
/// Clusters with heterogeneous link overrides are an error, not a silently
/// identity-only search: TP/DP configs cannot factor per-container
/// capacities yet.
pub fn joint_candidates(
    cluster: &ClusterSpec,
    w: &MoEWorkload,
    gpu: &GpuSpec,
    pe_tx_bytes: f64,
) -> Result<Vec<JointCandidate>> {
    ensure!(!cluster.levels.is_empty(), "cluster has no levels");
    ensure!(
        cluster.overrides.is_empty(),
        "joint parallelism search is not supported on clusters with \
         heterogeneous link overrides ({} on {:?}) — every non-identity \
         (tp, dp) would be rejected and the search would degenerate to the \
         identity without saying so",
        cluster.overrides.len(),
        cluster.name
    );
    let inner = cluster.levels.last().expect("levels non-empty").fanout;
    let outer = cluster.levels[0].fanout;
    let mut out = Vec::new();
    for pp in (1..=outer).filter(|p| outer % p == 0 && w.moe_layers % p == 0) {
        for tp in (1..=inner).filter(|t| inner % t == 0) {
            for dp in (1..=outer).filter(|d| outer % d == 0) {
                for &mb in MICROBATCH_GRID {
                    // microbatching is modeled through the pipeline only,
                    // and every microbatch must carry whole tokens
                    if (pp == 1 && mb > 1) || (w.tokens_per_gpu * pp) % mb != 0 {
                        continue;
                    }
                    let cfg = match ParallelismConfig::new_4d(cluster, pp, tp, dp, mb) {
                        Ok(c) => c,
                        // purely geometric misfit, e.g. pp·tp·dp beyond a
                        // single-level fanout — not a deployable point,
                        // skipping is correct
                        Err(_) => continue,
                    };
                    out.push(score_candidate(cluster, w, gpu, pe_tx_bytes, cfg)?);
                }
            }
        }
    }
    ensure!(!out.is_empty(), "no deployable (pp, tp, dp) candidate (identity always is)");
    out.sort_by(|a, b| {
        a.score
            .partial_cmp(&b.score)
            .expect("finite scores")
            .then(
                (a.config.pp * a.config.tp * a.config.dp * a.config.microbatches)
                    .cmp(&(b.config.pp * b.config.tp * b.config.dp * b.config.microbatches)),
            )
    });
    Ok(out)
}

fn score_candidate(
    cluster: &ClusterSpec,
    w: &MoEWorkload,
    gpu: &GpuSpec,
    pe_tx_bytes: f64,
    cfg: ParallelismConfig,
) -> Result<JointCandidate> {
    let vcluster = cfg.virtual_cluster(cluster)?;
    // DP tax: the expert-replica gradient ring over the slowest outer links
    // (gradients move raw expert bytes — the SR codec compresses migrated
    // weights, not gradients)
    let lat_dp = if cfg.dp > 1 {
        2.0 * (cfg.dp as f64 - 1.0) * w.experts_per_gpu as f64 * w.pe_bytes()
            / cluster.min_bandwidth_at(0)
    } else {
        0.0
    };
    let passes = if w.backward { 2.0 } else { 1.0 };
    if cfg.pp == 1 {
        // legacy 3D scoring — kept expression-for-expression so the pp = 1
        // plane of the 4D grid reproduces the historical scores bit-for-bit
        let input = crate::plan::parallel::member_plan_input(
            w,
            gpu,
            &cfg,
            cluster.total_gpus(),
            pe_tx_bytes,
        );
        let plan = plan_multilevel(&vcluster, &input)?;
        // TP tax: ring All-Reduce of the block activations per dense trunk
        // block + the MoE output, on the innermost (fast per-GPU) links
        let lat_tp = if cfg.tp > 1 {
            let payload = (w.pre_blocks + 1) as f64 * w.d_bytes();
            2.0 * (cfg.tp as f64 - 1.0) * payload
                / cluster.levels.last().expect("levels non-empty").bandwidth
        } else {
            0.0
        };
        let layer_latency = plan.predicted_latency + lat_tp;
        let score = passes * w.moe_layers as f64 * layer_latency + lat_dp;
        return Ok(JointCandidate { config: cfg, plan, layer_latency, score });
    }
    // pipeline candidate: each of the pp stages owns L/pp contiguous layers
    // and sees one microbatch (tokens × pp/M) at a time; the stage's virtual
    // cluster is the 4D virtual cluster itself (pp carves the outer level)
    let lps = w.moe_layers / cfg.pp;
    let stage_w = MoEWorkload {
        tokens_per_gpu: w.tokens_per_gpu * cfg.pp / cfg.microbatches,
        moe_layers: lps,
        ..*w
    };
    let input = crate::plan::parallel::member_plan_input(
        &stage_w,
        gpu,
        &cfg,
        cluster.total_gpus() / cfg.pp,
        pe_tx_bytes,
    );
    let plan = plan_multilevel(&vcluster, &input)?;
    let lat_tp = if cfg.tp > 1 {
        let payload = (w.pre_blocks + 1) as f64 * stage_w.d_bytes();
        2.0 * (cfg.tp as f64 - 1.0) * payload
            / cluster.levels.last().expect("levels non-empty").bandwidth
    } else {
        0.0
    };
    let layer_latency = plan.predicted_latency + lat_tp;
    let mb = cfg.microbatches as f64;
    // the 3D scores drop the expert-compute term (common to every (tp, dp)
    // point — it cancels in the Eq. 8 derivation), but the pipeline bubble
    // taxes it, so the slot length must carry it: per-microbatch expert
    // compute of one stage layer is C·pp/M with C the per-layer per-GPU
    // expert seconds
    let c_full = w.tokens_per_gpu as f64 * w.k as f64 * w.expert_macs_per_token()
        / gpu.macs_per_sec;
    let slot = lps as f64 * (layer_latency + c_full * cfg.pp as f64 / mb);
    // stage-boundary activation hop, priced on the slowest outer links
    let hop = stage_w.d_bytes() / cluster.min_bandwidth_at(0);
    // 1F1B with Sync::Window boundaries: one microbatch retires per
    // max(slot, hop) in steady state (the boundary link can be the pipeline
    // bottleneck), plus the fill/drain bubble — pp slots and pp − 1 exposed
    // boundary hops; subtracting the common expert-compute term puts the
    // score back on the 3D candidates' scale
    let makespan = (mb - 1.0) * slot.max(hop)
        + cfg.pp as f64 * slot
        + (cfg.pp as f64 - 1.0) * hop;
    let score = passes * (makespan - w.moe_layers as f64 * c_full) + lat_dp;
    Ok(JointCandidate { config: cfg, plan, layer_latency, score })
}

/// Joint `(p, pp, M, tp, dp)` optimum: the head of [`joint_candidates`]'s
/// best-first ordering (minimal per-iteration score; ties prefer fewer
/// parallel degrees — the identity when everything else is equal).
pub fn solve_joint(
    cluster: &ClusterSpec,
    w: &MoEWorkload,
    gpu: &GpuSpec,
    pe_tx_bytes: f64,
) -> Result<JointCandidate> {
    let cands = joint_candidates(cluster, w, gpu, pe_tx_bytes)?;
    Ok(cands.into_iter().next().expect("non-empty candidate set"))
}

// ---------------------------------------------------------------------------
// Simulation-backed joint search with deployment memoization
// ---------------------------------------------------------------------------

/// Counters of a [`solve_joint_simulated`] run: how many `(p, pp, M, tp,
/// dp)` grid points were scored vs how many **distinct resolved
/// deployments** were actually simulated. The gap is the memoization win —
/// many grid `p` values snap to the same deployable partition
/// (`p = 1 − S_ED/G` only takes divisor values), so scoring them again
/// would re-run an identical simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JointSimStats {
    /// `(p, pp, M, tp, dp)` grid points evaluated.
    pub points: usize,
    /// Distinct `(pp, M, tp, dp, snapped partition)` deployments simulated.
    pub simulated: usize,
}

/// Winner of the simulated joint search.
#[derive(Clone, Debug)]
pub struct SimulatedJoint {
    pub config: ParallelismConfig,
    /// Snapped per-virtual-level domain sizes of the winning deployment.
    pub partition_sizes: Vec<usize>,
    /// The requested grid `p` that first resolved to the winner.
    pub p: f64,
    /// Simulated iteration seconds of the winner.
    pub secs: f64,
    pub stats: JointSimStats,
}

/// Simulation-backed joint `(p, pp, M, tp, dp)` optimum: every deployable
/// `(pp, M, tp, dp)` factorization × every requested `p` is **snapped** to
/// its deployable partition on the candidate's virtual cluster and scored by
/// a full simulated iteration — with one simulation per *distinct* resolved
/// deployment. Distinct grid points that snap to the same `(pp, M, tp, dp,
/// partition)` key reuse the memoized makespan instead of re-simulating
/// (the duplicate-candidate perf fix; [`JointSimStats`] counts both sides).
/// Pipeline candidates simulate with overlap windows on (the planner's
/// default `pp_overlap = true`), so the search prices the overlapped
/// pipeline, bubbles and all.
///
/// Unlike the analytic [`solve_joint`], heterogeneous-override clusters are
/// accepted: the simulator prices overrides exactly, and non-identity
/// configs (which cannot factor overridden capacities) simply drop out of
/// the deployable set, leaving the identity-config `p` search.
pub fn solve_joint_simulated(
    cluster: &ClusterSpec,
    w: &MoEWorkload,
    routing: &crate::moe::Routing,
    p_grid: &[f64],
) -> Result<SimulatedJoint> {
    use crate::systems::hybrid_ep::HybridEp;
    use crate::systems::{SchedCtx, System};
    ensure!(!cluster.levels.is_empty(), "cluster has no levels");
    ensure!(!p_grid.is_empty(), "empty p grid — nothing to search");
    ensure!(
        routing.gpus() >= cluster.total_gpus(),
        "routing covers {} GPUs but the cluster has {}",
        routing.gpus(),
        cluster.total_gpus()
    );
    let inner = cluster.levels.last().expect("levels non-empty").fanout;
    let outer = cluster.levels[0].fanout;
    let mut memo: std::collections::HashMap<(usize, usize, usize, usize, Vec<usize>), f64> =
        std::collections::HashMap::new();
    let mut stats = JointSimStats::default();
    let mut best: Option<SimulatedJoint> = None;
    for pp in (1..=outer).filter(|p| outer % p == 0 && w.moe_layers % p == 0) {
        for tp in (1..=inner).filter(|t| inner % t == 0) {
            for dp in (1..=outer).filter(|d| outer % d == 0) {
                for &mb in MICROBATCH_GRID {
                    if (pp == 1 && mb > 1) || (w.tokens_per_gpu * pp) % mb != 0 {
                        continue;
                    }
                    let cfg = match ParallelismConfig::new_4d(cluster, pp, tp, dp, mb) {
                        Ok(c) => c,
                        Err(_) => continue, // not deployable on this cluster
                    };
                    let vcluster = cfg.virtual_cluster(cluster)?;
                    for &p in p_grid {
                        stats.points += 1;
                        let partition = crate::netsim::sweep::partition_for_p(&vcluster, p);
                        let key = (pp, mb, tp, dp, partition.clone());
                        let secs = match memo.get(&key) {
                            Some(&secs) => secs,
                            None => {
                                stats.simulated += 1;
                                let mut ctx = SchedCtx::new(cluster, w, routing);
                                ctx.parallelism = cfg;
                                let secs =
                                    HybridEp { partition: Some(partition.clone()), migration: None }
                                        .iteration_time(&ctx);
                                memo.insert(key, secs);
                                secs
                            }
                        };
                        let better = match &best {
                            None => true,
                            Some(b) => secs < b.secs,
                        };
                        if better {
                            best = Some(SimulatedJoint {
                                config: cfg,
                                partition_sizes: partition,
                                p,
                                secs,
                                stats, // overwritten with the final counters below
                            });
                        }
                    }
                }
            }
        }
    }
    let mut out = best.expect("identity config is always deployable");
    out.stats = stats;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Risk-aware planning: expected makespan under a failure-rate prior
// ---------------------------------------------------------------------------

/// Failure-rate prior for risk-aware planning: mean time between failures of
/// one DC and of one level-0 uplink, in seconds. Losses are assumed
/// independent and memoryless, so the cluster-wide loss rate is
/// `dcs · (1/dc + 1/link)` — every DC can die outright or drop off the
/// cluster with its uplink, and both look identical to the recovery layer.
#[derive(Clone, Copy, Debug)]
pub struct FailurePrior {
    /// MTBF of one DC (power, cooling, fabric), seconds.
    pub dc_mtbf_secs: f64,
    /// MTBF of one level-0 uplink, seconds.
    pub link_mtbf_secs: f64,
}

impl Default for FailurePrior {
    fn default() -> Self {
        // 30-day DC MTBF, 7-day WAN-uplink MTBF: conservative figures for
        // leased cross-DC capacity (uplinks fail an order of magnitude more
        // often than the facility behind them)
        Self { dc_mtbf_secs: 30.0 * 86_400.0, link_mtbf_secs: 7.0 * 86_400.0 }
    }
}

impl FailurePrior {
    /// Cluster-wide loss events per second: any of `dcs` containers lost to
    /// a DC failure or to its uplink failing.
    pub fn loss_rate(&self, dcs: usize) -> f64 {
        dcs as f64 * (1.0 / self.dc_mtbf_secs + 1.0 / self.link_mtbf_secs)
    }
}

/// Knobs of the risk-aware replication solver.
#[derive(Clone, Debug)]
pub struct RiskCfg {
    pub prior: FailurePrior,
    /// Iterations the plan is expected to run — the horizon replication
    /// overhead amortizes against.
    pub horizon_iters: usize,
    /// Checkpoint/restore pricing shared with the recovery layer (rollback
    /// redo, lazy re-host, amortized checkpoint tax).
    pub checkpoint: crate::migration::checkpoint::CheckpointCfg,
    /// Largest replication degree considered (clamped to the DC count — a
    /// ring cannot place more distinct copies than there are DCs).
    pub max_replicas: usize,
    /// Worst-case detection stall (`timeout + period`) paid before any
    /// recovery action can start.
    pub detect_stall_secs: f64,
}

impl Default for RiskCfg {
    fn default() -> Self {
        Self {
            prior: FailurePrior::default(),
            horizon_iters: 10_000,
            checkpoint: crate::migration::checkpoint::CheckpointCfg::default(),
            max_replicas: 3,
            detect_stall_secs: 1.0,
        }
    }
}

/// One scanned replication degree and its expected makespan.
#[derive(Clone, Debug)]
pub struct RiskPoint {
    pub r: usize,
    /// Expected horizon wall-clock: fault-free iterations + coherence tax +
    /// `E[losses] ·` per-loss recovery cost.
    pub expected_secs: f64,
    /// Steady-state replica memory per GPU (`r · shard_bytes`).
    pub memory_bytes_per_gpu: f64,
}

/// The risk-aware optimum: the replication degree (and ring placement)
/// minimizing expected makespan under the failure prior.
#[derive(Clone, Debug)]
pub struct RiskAwarePlan {
    pub r: usize,
    /// Ring placement for the chosen degree (`None` at `r = 1` — nothing is
    /// replicated, recovery falls back to checkpoint restore + rollback).
    pub replica: Option<crate::plan::replica::ReplicaPlan>,
    pub expected_secs: f64,
    /// The full scan, one point per candidate `r` (ascending).
    pub scan: Vec<RiskPoint>,
}

/// Choose the hot-standby replication degree `r` by **expected makespan**
/// under [`FailurePrior`]: each candidate `r` pays the SR-coded coherence
/// ring every iteration and, per expected loss event, either a decode-only
/// lazy re-host (`r ≥ 2` — a surviving replica covers any single loss, no
/// rollback) or a full checkpoint restore plus the expected half-interval
/// rollback redo (`r = 1`). The fault-free iteration is priced by the
/// stream model ([`plan_multilevel`] on the physical cluster), so the
/// trade is: replication tax × horizon vs loss rate × avoided recovery.
pub fn solve_replicated(
    cluster: &ClusterSpec,
    w: &MoEWorkload,
    gpu: &GpuSpec,
    pe_tx_bytes: f64,
    cfg: &RiskCfg,
) -> Result<RiskAwarePlan> {
    ensure!(cfg.horizon_iters >= 1, "risk horizon needs at least one iteration");
    ensure!(cfg.max_replicas >= 1, "max_replicas must be at least 1");
    ensure!(
        cfg.prior.dc_mtbf_secs > 0.0 && cfg.prior.link_mtbf_secs > 0.0,
        "failure prior MTBFs must be positive"
    );
    let dcs = cluster.levels[0].fanout;
    let gpus_per_dc: usize = cluster.levels[1..].iter().map(|l| l.fanout).product();
    let pe = w.pe_bytes();
    let lost_experts = gpus_per_dc.max(1) * w.experts_per_gpu;
    let passes = if w.backward { 2.0 } else { 1.0 };
    let plan = plan_multilevel(cluster, &w.plan_input(gpu, cluster.total_gpus(), pe_tx_bytes))?;
    let t_base = passes * w.moe_layers as f64 * plan.predicted_latency
        + cfg.checkpoint.amortized_secs_per_iter(cluster.total_gpus() * w.experts_per_gpu, pe);
    let rate = cfg.prior.loss_rate(dcs);
    let interval = cfg.checkpoint.interval_iters.max(1) as f64;

    let mut scan = Vec::new();
    let mut best: Option<(usize, f64)> = None;
    for r in 1..=cfg.max_replicas.min(dcs) {
        let rp = crate::plan::replica::ReplicaPlan::place(cluster, w, r)?;
        // the coherence ring ships SR residual frames (see plan::replanner)
        let coherence = rp.coherence_bytes_per_gpu()
            / cfg.checkpoint.codec.compression_ratio
            / cluster.min_bandwidth_at(0);
        let t_iter = t_base + coherence;
        let span = cfg.horizon_iters as f64 * t_iter;
        // any *single* DC loss is covered by a ring replica when r ≥ 2: the
        // copies sit on distinct DCs by construction
        let recover = if r >= 2 {
            cfg.checkpoint.lazy_rehost_secs(lost_experts, pe)
        } else {
            cfg.checkpoint.restore_secs(cluster, lost_experts, pe) + 0.5 * interval * t_iter
        };
        let per_loss = cfg.detect_stall_secs + recover;
        let expected = span + rate * span * per_loss;
        scan.push(RiskPoint {
            r,
            expected_secs: expected,
            memory_bytes_per_gpu: rp.memory_bytes_per_gpu(),
        });
        // ties prefer the smaller degree (less memory, smaller ring)
        if best.map_or(true, |(_, b)| expected < b) {
            best = Some((r, expected));
        }
    }
    let (r, expected_secs) = best.expect("max_replicas >= 1 yields a candidate");
    let replica = if r >= 2 {
        Some(crate::plan::replica::ReplicaPlan::place(cluster, w, r)?)
    } else {
        None
    };
    Ok(RiskAwarePlan { r, replica, expected_secs, scan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::migration::checkpoint::CheckpointCfg;
    use crate::prop_assert;
    use crate::testkit;

    /// Table IV rows: (p*, G, B Gbps, Lat_PE ms, D MB, P_E MB).
    ///
    /// NOTE: the paper prints `Lat_PE = 0.049 ms / 0.099 ms`, but those values
    /// are inconsistent with its own Eq. 11 boundary
    /// (`p* = 1 − B·Lat_PE/(P_E(G−1))` gives 0.976, not 0.75). With
    /// `Lat_PE = 0.49 ms / 0.99 ms` (one dropped digit) the formula lands
    /// exactly on the table's optima (0.76 → grid 0.75; 0.52 → grid 0.5), so
    /// we treat the printed values as a typo. Recorded in EXPERIMENTS.md.
    const TABLE_IV: &[(f64, usize, f64, f64, f64, f64)] = &[
        (0.75, 8, 128.0, 0.49, 8.0, 4.7),  // Mix-1
        (0.5, 8, 128.0, 0.49, 8.0, 2.35),  // Mix-2
        (0.0, 8, 128.0, 0.99, 3.0, 0.094), // AG-only-1
        (0.0, 8, 128.0, 0.99, 3.0, 0.047), // AG-only-2
    ];

    fn cfg_of(row: &(f64, usize, f64, f64, f64, f64)) -> StreamConfig {
        StreamConfig {
            g: row.1,
            d_bytes: row.4 * 1e6,
            pe_bytes: row.5 * 1e6,
            n_experts: 1,
            bandwidth: row.2 * 1e9 / 8.0,
            lat_pe: row.3 * 1e-3,
            lat_ep: 0.0,
        }
    }

    #[test]
    fn table_iv_optimal_p_on_grid() {
        // the paper's candidate grid for G=8: p ∈ {0, 0.5, 0.75, 1} — our
        // divisor grid adds S_ED=8 (p=0); the argmin must land on the paper's p.
        for row in TABLE_IV {
            let c = cfg_of(row);
            let got = solve_grid(&c);
            assert!(
                (got.p - row.0).abs() < 1e-9,
                "expected p={} got p={} (s_ed={}) for {row:?}",
                row.0,
                got.p,
                got.s_ed
            );
        }
    }

    #[test]
    fn table_iv_cases() {
        assert_eq!(solve_continuous(&cfg_of(&TABLE_IV[0])).case, SolveCase::Mixed);
        assert_eq!(solve_continuous(&cfg_of(&TABLE_IV[1])).case, SolveCase::Mixed);
        assert_eq!(solve_continuous(&cfg_of(&TABLE_IV[2])).case, SolveCase::AgOnly);
        assert_eq!(solve_continuous(&cfg_of(&TABLE_IV[3])).case, SolveCase::AgOnly);
    }

    #[test]
    fn grid_optimum_is_brute_force_optimum() {
        testkit::check("grid-argmin", 200, |g| {
            let c = StreamConfig {
                g: [2usize, 4, 6, 8, 12, 16, 32][g.usize_in(0, 7)],
                d_bytes: g.rng.f64() * 2e8 + 1e3,
                pe_bytes: g.rng.f64() * 3e7 + 1e3,
                n_experts: g.usize_in(1, 5),
                bandwidth: g.rng.f64() * 2e10 + 1e8,
                lat_pe: g.rng.f64() * 5e-3,
                lat_ep: g.rng.f64() * 1e-4,
            };
            let got = solve_grid(&c);
            for cand in grid_candidates(&c) {
                prop_assert!(
                    got.latency <= cand.latency + 1e-15,
                    "candidate s_ed={} beats chosen s_ed={}: {} < {}",
                    cand.s_ed,
                    got.s_ed,
                    cand.latency,
                    got.latency
                );
            }
            Ok(())
        });
    }

    #[test]
    fn continuous_beats_or_matches_grid() {
        testkit::check("continuous-le-grid", 100, |g| {
            let c = StreamConfig {
                g: g.usize_in(2, 40),
                d_bytes: g.rng.f64() * 1e8 + 1e3,
                pe_bytes: g.rng.f64() * 1e7 + 1e3,
                n_experts: g.usize_in(1, 4),
                bandwidth: g.rng.f64() * 1e10 + 1e8,
                lat_pe: g.rng.f64() * 1e-2,
                lat_ep: 0.0,
            };
            let cont = solve_continuous(&c);
            // continuous optimum is optimal over a dense sweep
            for i in 0..=100 {
                let p = i as f64 / 100.0;
                prop_assert!(
                    cont.latency <= c.lat_final(p) + 1e-12,
                    "p={p} beats continuous p*={}: {} < {}",
                    cont.p_star,
                    c.lat_final(p),
                    cont.latency
                );
            }
            Ok(())
        });
    }

    #[test]
    fn p1_degenerates_to_ep() {
        let c = cfg_of(&TABLE_IV[0]);
        let ep = c.lat_final(1.0);
        let hybrid = solve_grid(&c).latency;
        assert!(hybrid <= ep);
    }

    /// Satellite property: the deployable (grid) optimum can never beat the
    /// continuous optimum — the divisor grid is a subset of [0, 1].
    #[test]
    fn grid_optimum_never_beats_continuous() {
        testkit::check("grid-ge-continuous", 200, |g| {
            let c = StreamConfig {
                g: g.usize_in(2, 48),
                d_bytes: g.rng.f64() * 2e8 + 1e3,
                pe_bytes: g.rng.f64() * 3e7 + 1e3,
                n_experts: g.usize_in(1, 5),
                bandwidth: g.rng.f64() * 2e10 + 1e8,
                lat_pe: g.rng.f64() * 5e-3,
                lat_ep: g.rng.f64() * 1e-4,
            };
            let cont = solve_continuous(&c);
            let grid = solve_grid(&c);
            prop_assert!(
                grid.latency >= cont.latency - 1e-15 * (1.0 + cont.latency.abs()),
                "grid optimum {} (s_ed={}) beats continuous optimum {} (p*={})",
                grid.latency,
                grid.s_ed,
                cont.latency,
                cont.p_star
            );
            Ok(())
        });
    }

    /// Satellite property: `p = 1` (`S_ED = 1` everywhere) makes HybridEP's
    /// simulated iteration match `VanillaEp` — "EP is a special case of
    /// HybridEP" (§III-E). On a single-level cluster the unit-domain
    /// hierarchical schedule *is* pairwise EP, so the match is tight.
    #[test]
    fn unit_domains_match_vanilla_ep_simulated() {
        use crate::moe::{MoEWorkload, Routing};
        use crate::systems::ep::VanillaEp;
        use crate::systems::hybrid_ep::HybridEp;
        use crate::systems::{SchedCtx, System};
        testkit::check("sed1-is-vanilla-ep", 25, |g| {
            let gpus = [4usize, 6, 8][g.usize_in(0, 3)];
            let cluster = crate::cluster::presets::flat_dcs(gpus, 10.0);
            let w = MoEWorkload {
                tokens_per_gpu: 64 * g.usize_in(1, 5),
                hidden: 64,
                ffn: 128,
                experts_per_gpu: g.usize_in(1, 3),
                k: 1,
                moe_layers: g.usize_in(1, 3),
                pre_blocks: 1,
                backward: false,
            };
            let routing = if g.rng.below(2) == 0 {
                Routing::uniform(gpus, gpus * w.experts_per_gpu, w.tokens_per_gpu, w.k)
            } else {
                Routing::zipf(
                    gpus,
                    gpus * w.experts_per_gpu,
                    w.tokens_per_gpu,
                    w.k,
                    1.3,
                    g.rng.below(1000) as u64,
                )
            };
            let ctx = SchedCtx::new(&cluster, &w, &routing);
            let ep = VanillaEp.iteration_time(&ctx);
            let hy =
                HybridEp { partition: Some(vec![1]), migration: None }.iteration_time(&ctx);
            prop_assert!(
                (hy - ep).abs() / ep < 1e-6,
                "S_ED=1 HybridEP {hy} != VanillaEP {ep} on {gpus} GPUs"
            );
            Ok(())
        });
        // multilevel: unit domains relay through mirrors; with fast inner
        // links the relay overhead is bounded, so EP is matched loosely
        let cluster = crate::cluster::presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 1024,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let routing = Routing::uniform(8, 8, w.tokens_per_gpu, w.k);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let ep = VanillaEp.iteration_time(&ctx);
        let hy = HybridEp { partition: Some(vec![1, 1]), migration: None }.iteration_time(&ctx);
        assert!(
            (hy - ep).abs() / ep < 0.2,
            "multilevel unit-domain relay strayed too far from EP: {hy} vs {ep}"
        );
    }

    #[test]
    fn per_layer_profile_never_worse_than_any_global_partition() {
        // pointwise argmin ≤ any fixed choice, summed over layers (exact on
        // single-level clusters, where the grid argmin is exhaustive)
        testkit::check("per-layer-le-global", 80, |g| {
            let gpus = [4usize, 8, 12][g.usize_in(0, 3)];
            let bw_gbps = g.rng.f64() * 20.0 + 1.0;
            let cluster = crate::cluster::presets::flat_dcs(gpus, bw_gbps);
            let inputs: Vec<PlanInput> = (0..g.usize_in(1, 5))
                .map(|_| PlanInput {
                    d_bytes: g.rng.f64() * 1e8 + 1e3,
                    pe_bytes: g.rng.f64() * 1e7 + 1e3,
                    n_experts: g.usize_in(1, 3),
                    lat_pe: g.rng.f64() * 2e-3,
                    lat_ep: g.rng.f64() * 1e-4,
                })
                .collect();
            let plans = plan_layers(&cluster, &inputs).map_err(|e| e.to_string())?;
            let per_layer: f64 = plans.iter().map(|p| p.predicted_latency).sum();
            let bandwidth = cluster.levels[0].bandwidth;
            for s_ed in (1..=gpus).filter(|s| gpus % s == 0) {
                let p = p_of_domain(gpus, s_ed);
                let total: f64 = inputs
                    .iter()
                    .map(|input| {
                        StreamConfig {
                            g: gpus,
                            d_bytes: input.d_bytes,
                            pe_bytes: input.pe_bytes,
                            n_experts: input.n_experts,
                            bandwidth,
                            lat_pe: input.lat_pe,
                            lat_ep: input.lat_ep,
                        }
                        .lat_final(p)
                    })
                    .sum();
                prop_assert!(
                    per_layer <= total + 1e-9 * (1.0 + total.abs()),
                    "per-layer profile {per_layer} worse than fixed S_ED={s_ed} at {total}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn joint_identity_candidate_matches_plain_multilevel() {
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 2048,
            hidden: 512,
            ffn: 1024,
            experts_per_gpu: 2,
            k: 2,
            moe_layers: 4,
            pre_blocks: 1,
            backward: true,
        };
        let gpu = GpuSpec::a800();
        let pe_tx = w.pe_bytes() / 50.0;
        let cands = joint_candidates(&cluster, &w, &gpu, pe_tx).unwrap();
        let id = cands.iter().find(|c| c.config.is_identity()).expect("identity candidate");
        let direct =
            plan_multilevel(&cluster, &w.plan_input(&gpu, cluster.total_gpus(), pe_tx)).unwrap();
        assert_eq!(id.plan.partition_sizes, direct.partition_sizes);
        assert_eq!(
            id.plan.predicted_latency.to_bits(),
            direct.predicted_latency.to_bits(),
            "identity candidate must reproduce the plain multilevel plan bit-for-bit"
        );
        assert_eq!(id.layer_latency.to_bits(), direct.predicted_latency.to_bits());
    }

    #[test]
    fn joint_candidates_sorted_best_first_and_reject_override_clusters() {
        let cluster = presets::dcs_x_gpus(2, 4, 1.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 8192,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 6,
            pre_blocks: 1,
            backward: true,
        };
        let gpu = GpuSpec::a800();
        let cands = joint_candidates(&cluster, &w, &gpu, w.pe_bytes()).unwrap();
        for pair in cands.windows(2) {
            assert!(pair[0].score <= pair[1].score, "candidates must be sorted best-first");
        }
        let best = solve_joint(&cluster, &w, &gpu, w.pe_bytes()).unwrap();
        assert_eq!(best.config, cands[0].config, "solve_joint is the list head");
        // heterogeneous clusters are a descriptive error, not a silently
        // identity-only search
        let het = presets::straggler_dc(2, 4, 10.0, 128.0, 0, 2.5);
        let err = joint_candidates(&het, &w, &gpu, w.pe_bytes()).unwrap_err().to_string();
        assert!(err.contains("overrides"), "unexpected error: {err}");
        assert!(solve_joint(&het, &w, &gpu, w.pe_bytes()).is_err());
    }

    #[test]
    fn joint_prefers_identity_when_experts_dominate() {
        // huge raw experts, modest data: replicating experts across DCs
        // (dp) or paying TP activation reductions buys nothing. Pipelining
        // is a different story — it moves *no* experts, so the 4D best may
        // legitimately open pp here; the claim is about the TED plane.
        let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 512,
            ffn: 8192,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 2,
            pre_blocks: 1,
            backward: true,
        };
        let cands = joint_candidates(&cluster, &w, &GpuSpec::a800(), w.pe_bytes()).unwrap();
        // candidates are sorted best-first, so the first pp = 1 entry is the
        // best 3D (TED) candidate
        let best3d = cands.iter().find(|c| c.config.pp == 1).expect("pp=1 plane present");
        assert!(best3d.config.is_identity(), "expected pure EP, got {:?}", best3d.config);
    }

    #[test]
    fn joint_opens_dp_under_constrained_uplink_with_small_experts() {
        // 1 Gbps uplink, small raw experts, heavy activations: keeping the
        // forward pass inside each DC and paying one expert-gradient ring
        // beats every per-layer cross-DC exchange
        let cluster = presets::dcs_x_gpus(2, 4, 1.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 8192,
            hidden: 256,
            ffn: 512,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 6,
            pre_blocks: 1,
            backward: true,
        };
        let gpu = GpuSpec::a800();
        let best = solve_joint(&cluster, &w, &gpu, w.pe_bytes()).unwrap();
        // the 4D grid may open pp instead — any non-EP dimension keeps the
        // per-layer exchange off the starved uplink
        assert!(
            best.config.tp > 1 || best.config.dp > 1 || best.config.pp > 1,
            "constrained uplink must open PP, TP or DP, got {:?}",
            best.config
        );
        let cands = joint_candidates(&cluster, &w, &gpu, w.pe_bytes()).unwrap();
        let id = cands.iter().find(|c| c.config.is_identity()).expect("identity candidate");
        assert!(
            best.score < id.score,
            "joint pick {:?} ({}) must beat identity ({})",
            best.config,
            best.score,
            id.score
        );
    }

    /// The 4D grid carries pipeline candidates: every deployable pp > 1
    /// point appears with each feasible microbatch count, the bubble tax
    /// makes more microbatches (weakly) cheaper on a compute-scaled stage,
    /// and under a starved uplink the best pipeline candidate crushes the
    /// identity (its per-layer exchange stays inside the DC).
    #[test]
    fn joint_4d_prices_pipeline_candidates_with_bubble_tax() {
        // deep model, huge raw experts, light activations on a starved
        // 1 Gbps uplink: the identity pays a cross-DC exchange on all 12
        // layers while a 2-stage pipeline pays M boundary hops total
        let cluster = presets::dcs_x_gpus(2, 4, 1.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 512,
            ffn: 8192,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 12,
            pre_blocks: 1,
            backward: true,
        };
        let gpu = GpuSpec::a800();
        let cands = joint_candidates(&cluster, &w, &gpu, w.pe_bytes()).unwrap();
        // outer fanout 2, 12 layers → pp ∈ {1, 2}; tokens·pp = 512 divides
        // every MICROBATCH_GRID count, so all four mb points deploy
        for &mb in MICROBATCH_GRID {
            assert!(
                cands.iter().any(|c| c.config.pp == 2 && c.config.microbatches == mb),
                "missing (pp=2, M={mb}) candidate"
            );
        }
        assert!(cands.iter().all(|c| c.config.pp == 1 || c.config.pp == 2));
        assert!(
            cands.iter().all(|c| (c.config.pp == 1) == (c.config.microbatches == 1)),
            "microbatching without a pipeline (or a forced M=1 pipeline grid) leaked in"
        );
        // more microbatches amortize the fill/drain bubble: M=8 ≤ M=1 at pp=2
        let score_at = |mb: usize| {
            cands
                .iter()
                .filter(|c| c.config.pp == 2 && c.config.tp == 1 && c.config.dp == 1)
                .find(|c| c.config.microbatches == mb)
                .expect("pp=2 tp=1 dp=1 candidate")
                .score
        };
        assert!(
            score_at(8) <= score_at(1) * (1.0 + 1e-9),
            "bubble tax not amortized: M=8 {} vs M=1 {}",
            score_at(8),
            score_at(1)
        );
        // at 1 Gbps the pipelined stages (all traffic intra-DC except the
        // boundary hops) must beat the identity's cross-DC per-layer A2A
        let id = cands.iter().find(|c| c.config.is_identity()).expect("identity").score;
        let best_pp =
            cands.iter().filter(|c| c.config.pp == 2).map(|c| c.score).fold(f64::MAX, f64::min);
        assert!(best_pp < id, "pipeline {best_pp} must beat identity {id} at 1 Gbps");
    }

    /// Satellite (perf fix): the simulated `(p, pp, M, tp, dp)` grid search snaps
    /// many grid `p` values onto the same deployable partition; the memo
    /// must collapse those duplicates to one simulation each — counted, not
    /// assumed.
    #[test]
    fn simulated_joint_memoizes_duplicate_deployments() {
        use crate::moe::Routing;
        let cluster = presets::dcs_x_gpus(2, 2, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 64,
            ffn: 128,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let g = cluster.total_gpus();
        let routing = Routing::uniform(g, g, w.tokens_per_gpu, w.k);
        let p_grid: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
        let best = solve_joint_simulated(&cluster, &w, &routing, &p_grid).unwrap();
        // deployable (tp, dp) ∈ {1,2}×{1,2} → 4 configs × 11 p points
        assert_eq!(best.stats.points, 4 * p_grid.len());
        assert!(
            best.stats.simulated < best.stats.points,
            "duplicate deployments were re-simulated: {:?}",
            best.stats
        );
        // per config at most |divisor partitions| ≤ 4 distinct deployments
        assert!(best.stats.simulated <= 16, "{:?}", best.stats);
        // the winner is a real minimum: re-simulating its deployment and the
        // identity pure-EP point can't beat it
        let id_cfg = ParallelismConfig::identity(g);
        let mut ctx = crate::systems::SchedCtx::new(&cluster, &w, &routing);
        ctx.parallelism = id_cfg;
        let pure_ep = crate::systems::hybrid_ep::HybridEp {
            partition: Some(crate::netsim::sweep::partition_for_p(&cluster, 1.0)),
            migration: None,
        };
        use crate::systems::System;
        let ep_secs = pure_ep.iteration_time(&ctx);
        assert!(
            best.secs <= ep_secs * (1.0 + 1e-9),
            "simulated optimum {} loses to pure EP {}",
            best.secs,
            ep_secs
        );
        // determinism: same grid, same counters, same winner
        let again = solve_joint_simulated(&cluster, &w, &routing, &p_grid).unwrap();
        assert_eq!(again.stats, best.stats);
        assert_eq!(again.secs.to_bits(), best.secs.to_bits());
        assert_eq!(again.partition_sizes, best.partition_sizes);
        // degenerate grids are descriptive errors
        assert!(solve_joint_simulated(&cluster, &w, &routing, &[]).is_err());
    }

    /// The simulated grid walks the pipeline axis too: pp over outer-level
    /// divisors that tile the layer count, with the microbatch count
    /// tunable, and every (pp, M, tp, dp, partition) deployment simulated
    /// at most once.
    #[test]
    fn simulated_joint_searches_the_pipeline_axis() {
        use crate::moe::Routing;
        let cluster = presets::dcs_x_gpus(2, 2, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 64,
            ffn: 128,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let g = cluster.total_gpus();
        let routing = Routing::uniform(g, g, w.tokens_per_gpu, w.k);
        let p_grid: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
        let best = solve_joint_simulated(&cluster, &w, &routing, &p_grid).unwrap();
        // pp=1 plane: (tp, dp) ∈ {1,2}² → 4 configs (M = 1 forced); pp=2
        // plane: dp = 1 forced (pp·dp must divide the 2-DC outer level),
        // tp ∈ {1,2}, M ∈ {1,2,4,8} → 8 configs; 12 configs × 11 p points
        assert_eq!(best.stats.points, 12 * p_grid.len());
        assert!(best.stats.simulated < best.stats.points, "{:?}", best.stats);
        assert!(best.secs.is_finite() && best.secs > 0.0);
        // determinism across reruns
        let again = solve_joint_simulated(&cluster, &w, &routing, &p_grid).unwrap();
        assert_eq!(again.secs.to_bits(), best.secs.to_bits());
        assert_eq!(again.config, best.config);
        assert_eq!(again.stats, best.stats);
    }

    /// Heterogeneous-override clusters degrade gracefully to the
    /// identity-config `p` search (the simulator prices overrides exactly),
    /// instead of erroring like the analytic solver.
    #[test]
    fn simulated_joint_accepts_override_clusters_identity_only() {
        use crate::moe::Routing;
        let het = presets::straggler_dc(2, 2, 10.0, 128.0, 0, 2.5);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 64,
            ffn: 128,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let g = het.total_gpus();
        let routing = Routing::uniform(g, g, w.tokens_per_gpu, w.k);
        let best = solve_joint_simulated(&het, &w, &routing, &[0.0, 0.5, 1.0]).unwrap();
        assert!(best.config.is_identity(), "only the identity factors an overridden cluster");
        assert_eq!(best.stats.points, 3, "non-identity configs must drop out, not error");
        assert!(best.secs.is_finite() && best.secs > 0.0);
    }

    /// Risk-aware replication: a hot-failure regime (hours-scale MTBF on a
    /// starved uplink) must open r ≥ 2 — the coherence tax is dwarfed by the
    /// avoided rollback redo — while a near-zero failure rate keeps r = 1
    /// (replication is pure overhead with nothing to recover from).
    #[test]
    fn risk_aware_replication_tracks_the_failure_prior() {
        let cluster = presets::dcs_x_gpus(4, 2, 1.0, 128.0);
        // raw expert transfers (pe_tx uncompressed) on a 1 Gbps uplink pin
        // the fault-free iteration in the ≥ 10 ms range, so the avoided
        // half-interval rollback dwarfs the SR-coded coherence ring
        let w = MoEWorkload {
            tokens_per_gpu: 4096,
            hidden: 256,
            ffn: 2048,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 2,
            pre_blocks: 1,
            backward: false,
        };
        let gpu = GpuSpec::a800();
        let pe_tx = w.pe_bytes();

        let hot = RiskCfg {
            // chaos-regime prior (losses every few minutes) with a long
            // checkpoint interval: rollback redo is the dominant loss cost
            prior: FailurePrior { dc_mtbf_secs: 60.0, link_mtbf_secs: 60.0 },
            checkpoint: CheckpointCfg { interval_iters: 1000, ..CheckpointCfg::default() },
            ..RiskCfg::default()
        };
        let risky = solve_replicated(&cluster, &w, &gpu, pe_tx, &hot).unwrap();
        assert!(risky.r >= 2, "hours-scale MTBF must buy replicas, got r = {}", risky.r);
        let rp = risky.replica.as_ref().expect("r >= 2 carries a placement");
        assert_eq!(rp.r, risky.r);

        let calm = RiskCfg {
            prior: FailurePrior { dc_mtbf_secs: 1e15, link_mtbf_secs: 1e15 },
            ..RiskCfg::default()
        };
        let safe = solve_replicated(&cluster, &w, &gpu, pe_tx, &calm).unwrap();
        assert_eq!(safe.r, 1, "a failure-free prior must not pay for replicas");
        assert!(safe.replica.is_none());
        assert!(safe.expected_secs < risky.expected_secs, "risk must cost");

        // the scan is complete, ascending in r, and the pick is its argmin
        for plan in [&risky, &safe] {
            assert_eq!(plan.scan.len(), 3, "max_replicas 3 on 4 DCs scans r = 1..=3");
            for (i, pt) in plan.scan.iter().enumerate() {
                assert_eq!(pt.r, i + 1);
                assert!(pt.expected_secs.is_finite() && pt.expected_secs > 0.0);
                assert!(pt.expected_secs >= plan.expected_secs, "scan beats the pick");
                assert!(pt.memory_bytes_per_gpu >= pt.r as f64 * 0.9 * w.pe_bytes());
            }
        }

        // degenerate priors and horizons are descriptive errors
        let bad = RiskCfg { horizon_iters: 0, ..RiskCfg::default() };
        let err = solve_replicated(&cluster, &w, &gpu, pe_tx, &bad).unwrap_err().to_string();
        assert!(err.contains("horizon"), "unexpected error: {err}");
        let bad = RiskCfg {
            prior: FailurePrior { dc_mtbf_secs: 0.0, link_mtbf_secs: 1.0 },
            ..RiskCfg::default()
        };
        assert!(solve_replicated(&cluster, &w, &gpu, pe_tx, &bad).is_err());
    }

    /// The ring cannot place more distinct copies than there are DCs:
    /// `max_replicas` is clamped, never an error.
    #[test]
    fn risk_scan_clamps_replicas_to_the_dc_count() {
        let cluster = presets::dcs_x_gpus(2, 2, 10.0, 128.0);
        let w = MoEWorkload {
            tokens_per_gpu: 256,
            hidden: 64,
            ffn: 128,
            experts_per_gpu: 1,
            k: 1,
            moe_layers: 1,
            pre_blocks: 1,
            backward: false,
        };
        let cfg = RiskCfg { max_replicas: 8, ..RiskCfg::default() };
        let plan =
            solve_replicated(&cluster, &w, &GpuSpec::a800(), w.pe_bytes(), &cfg).unwrap();
        assert_eq!(plan.scan.len(), 2, "two DCs cap the scan at r = 2");
        assert!(plan.r <= 2);
    }

    #[test]
    fn multilevel_plan_cluster_m() {
        let w = PlanInput {
            d_bytes: 24e6,
            pe_bytes: 8e6,
            n_experts: 2,
            lat_pe: 2e-3,
            lat_ep: 0.5e-3,
        };
        let plan = plan_multilevel(&presets::cluster_m(), &w).unwrap();
        assert_eq!(plan.partition_sizes.len(), 3);
        let ml = presets::cluster_m().multilevel();
        let part = plan.partition(&ml).unwrap();
        // partition is valid & p decreases latency vs vanilla EP
        assert_eq!(part.sizes().len(), 3);
        assert!(plan.predicted_latency > 0.0);
    }

    #[test]
    fn lower_bandwidth_wants_bigger_domains() {
        // at very low inter-DC bandwidth with small experts, AG-only should win
        let mk = |bw_gbps: f64| StreamConfig {
            g: 8,
            d_bytes: 64e6,
            pe_bytes: 0.36e6,
            n_experts: 1,
            bandwidth: bw_gbps * 1e9 / 8.0,
            lat_pe: 1e-3,
            lat_ep: 0.0,
        };
        let slow = solve_grid(&mk(10.0));
        assert_eq!(slow.s_ed, 8, "cheap experts + expensive data → AG-only");
        let speedup = mk(10.0).lat_final(1.0) / slow.latency;
        assert!(speedup > 2.0, "expected big win under low bandwidth, got {speedup}");
    }
}
