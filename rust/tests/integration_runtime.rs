//! Integration tests over the real runtime: artifacts → PJRT → trainer →
//! coordinator. Skipped gracefully when `make artifacts` hasn't run.

use hybrid_ep::cluster::presets;
use hybrid_ep::coordinator::{run_cross_dc, CrossDcCfg};
use hybrid_ep::runtime::{Artifacts, Engine};
use hybrid_ep::trainer::{Compression, Trainer};

fn arts() -> Option<Artifacts> {
    match Artifacts::discover() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("skipping runtime integration: {e}");
            None
        }
    }
}

#[test]
fn e2e_short_training_loss_decreases() {
    let Some(arts) = arts() else { return };
    let mut engine = Engine::cpu().unwrap();
    let mut t = Trainer::new(&mut engine, &arts, "test", 1).unwrap();
    for _ in 0..40 {
        t.step().unwrap();
    }
    let first = t.losses()[..5].iter().sum::<f32>() / 5.0;
    let last = t.recent_loss(5);
    assert!(last < first, "no learning: {first} → {last}");
    // eval runs and is in a sane range
    let ev = t.eval().unwrap();
    assert!(ev.is_finite() && ev > 0.5 && ev < 8.0);
}

#[test]
fn fig14_ordering_holds_on_short_run() {
    let Some(arts) = arts() else { return };
    let mut finals = Vec::new();
    for comp in [
        Compression::None,
        Compression::WithShared { cr: 50 },
        Compression::WithoutShared { cr: 50 },
    ] {
        let mut engine = Engine::cpu().unwrap();
        let mut t = Trainer::new(&mut engine, &arts, "test", 42).unwrap();
        t.compression = comp;
        for _ in 0..25 {
            t.step().unwrap();
        }
        finals.push(t.recent_loss(5));
    }
    let (base, ws, wos) = (finals[0], finals[1], finals[2]);
    assert!(
        (ws - base).abs() <= (wos - base).abs() + 0.05,
        "w/S ({ws}) should track baseline ({base}) better than w/o S ({wos})"
    );
}

#[test]
fn cross_dc_runtime_full_pipeline() {
    let Some(arts) = arts() else { return };
    let cfg = CrossDcCfg {
        cluster: presets::dcs_x_gpus(2, 4, 40.0, 512.0),
        time_scale: 40.0,
        partition: vec![2, 4],
        compression_ratio: Some(50),
        iterations: 2,
        seed: 3,
    };
    let stats = run_cross_dc(&arts, &cfg).unwrap();
    assert_eq!(stats.len(), 2);
    // full-domain: all data local, only compressed AG bytes move
    assert_eq!(stats[0].a2a_bytes, 0);
    assert!(stats[0].ag_bytes > 0);
    assert!(stats[1].sim_secs > 0.0);
}

#[test]
fn train_step_is_deterministic_given_seed() {
    let Some(arts) = arts() else { return };
    let run = || {
        let mut engine = Engine::cpu().unwrap();
        let mut t = Trainer::new(&mut engine, &arts, "test", 9).unwrap();
        for _ in 0..5 {
            t.step().unwrap();
        }
        t.losses()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "training must be reproducible from the seed");
}
