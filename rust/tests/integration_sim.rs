//! Integration tests over the simulation stack: paper-shape assertions that
//! span cluster + topology + model + netsim + systems.

use hybrid_ep::cluster::presets;
use hybrid_ep::moe::{MoEWorkload, Routing};
use hybrid_ep::netsim::sweep;
use hybrid_ep::report::experiments as exp;
use hybrid_ep::systems::aggregate::AggregateHybrid;
use hybrid_ep::systems::hybrid_ep::HybridEp;
use hybrid_ep::systems::{comparison_set, ep, SchedCtx, System};

fn ctx_parts(
    d_mb: f64,
    e_mb: f64,
    cluster: hybrid_ep::cluster::ClusterSpec,
) -> (hybrid_ep::cluster::ClusterSpec, MoEWorkload, Routing) {
    let w = exp::workload_from_sizes(d_mb * 1e6, e_mb * 1e6, 4, true);
    let routing = Routing::uniform(
        cluster.total_gpus(),
        cluster.total_gpus() * w.experts_per_gpu,
        w.tokens_per_gpu,
        w.k,
    );
    (cluster, w, routing)
}

#[test]
fn table5_shape_hybrid_flat_baselines_linear() {
    // the paper's headline: baselines grow ~linearly in data traffic while
    // HybridEP stays nearly flat, crossing 2× speedup by 48 MB on Cluster-L
    let (_, cells) = exp::table5(&[6.0, 48.0, 192.0]);
    let t = |sys: &str, mb: f64| {
        cells
            .iter()
            .find(|c| c.cluster == "Cluster-L" && c.system == sys && c.data_mb == mb)
            .unwrap()
            .secs
    };
    // baselines scale strongly with traffic
    assert!(t("Tutel", 192.0) > 4.0 * t("Tutel", 6.0));
    // hybrid is nearly flat
    assert!(t("HybridEP", 192.0) < 1.3 * t("HybridEP", 6.0));
    // speedup at max traffic lands in the paper's neighbourhood (≥3×)
    let speedup = t("Tutel", 192.0) / t("HybridEP", 192.0);
    assert!(speedup > 3.0, "speedup {speedup}");
}

#[test]
fn fig13_shape_speedup_grows_as_experts_shrink() {
    let (_, cells) = exp::fig13(&[32.0, 2.0]);
    for cl in ["Cluster-M", "Cluster-L"] {
        let t = |sys: &str, mb: f64| {
            cells
                .iter()
                .find(|c| c.cluster == cl && c.system == sys && c.expert_mb == mb)
                .unwrap()
                .secs
        };
        let s_big = t("Tutel", 32.0) / t("HybridEP", 32.0);
        let s_small = t("Tutel", 2.0) / t("HybridEP", 2.0);
        assert!(
            s_small > s_big,
            "{cl}: speedup should grow as experts shrink: {s_big} → {s_small}"
        );
        assert!(s_small > 1.1, "{cl}: small experts must win clearly, got {s_small}");
    }
}

#[test]
fn every_system_beats_nothing_and_hybrid_never_loses_badly() {
    // sanity across the full comparison set on a mid-sized workload
    let (cluster, w, routing) = ctx_parts(24.0, 4.0, exp::paper_cluster_m());
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let vanilla = ep::VanillaEp.iteration_time(&ctx);
    for sys in comparison_set() {
        let t = sys.iteration_time(&ctx);
        assert!(t <= vanilla * 1.05, "{} ({t}) worse than blocking EP ({vanilla})", sys.name());
    }
    let hybrid = HybridEp::with_migration().iteration_time(&ctx);
    assert!(hybrid <= vanilla, "hybrid must not lose to vanilla EP");
}

#[test]
fn fig17_scales_and_shows_modest_gain_at_1000_dcs() {
    let w = MoEWorkload {
        tokens_per_gpu: 8192,
        hidden: 1024,
        ffn: 2048,
        experts_per_gpu: 1,
        k: 2,
        moe_layers: 2,
        pre_blocks: 1,
        backward: false,
    };
    let routing = Routing::uniform(1, 1, 1, 1);
    let cluster = presets::flat_dcs(1000, 5.0);
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let t0 = std::time::Instant::now();
    let ep_t = AggregateHybrid::ep().iteration_time(&ctx);
    let hy_t = AggregateHybrid::hybrid(10, w.pe_bytes() / 50.0).iteration_time(&ctx);
    assert!(t0.elapsed().as_secs_f64() < 30.0, "1000-DC sim too slow");
    let speedup = ep_t / hy_t;
    assert!(
        (1.0..2.5).contains(&speedup),
        "1000-DC fixed-S speedup {speedup} out of the paper's plausible band"
    );
}

#[test]
fn fig17_per_dc_axis_completes_at_256_dcs() {
    // the symmetry-folded dense rows: 256 DCs × 4 GPUs/DC = 1024 GPUs,
    // ~1M member flows per dispatch phase materialized as ~O(D²) macros
    let t0 = std::time::Instant::now();
    let (_t, rows) = exp::fig17_axes(&[256], &[4], sweep::default_threads());
    assert!(t0.elapsed().as_secs_f64() < 120.0, "per_dc rows too slow");
    let dense: Vec<_> = rows.iter().filter(|r| r.per_dc == 4).collect();
    assert_eq!(dense.len(), 2, "one folded dense row per mode");
    for r in &dense {
        assert_eq!(r.dcs, 256);
        assert!(
            r.speedup.is_finite() && r.speedup > 0.8 && r.speedup < 10.0,
            "{}: per_dc speedup {} outside the plausible band",
            r.fixed,
            r.speedup
        );
    }
    // the domain cut both the message frequency and the cross-DC share, so
    // the hybrid must win on at least one mode at 5 Gbps
    assert!(dense.iter().any(|r| r.speedup > 1.0), "folded hybrid never won");
}

#[test]
fn fig17_scale_sweep_parallel_deterministic_and_wins() {
    // acceptance: a ≥256-DC fig17-style sweep completes under the parallel
    // harness, is bit-identical to the serial run, and the incremental
    // engine agrees with the reference oracle on the same scenario
    let mut grid = sweep::SweepGrid::fig17(vec![256]);
    grid.bandwidths_gbps = vec![2.5];
    grid.workload.moe_layers = 2;
    let t0 = std::time::Instant::now();
    let serial = sweep::run_sweep(&grid, 1).unwrap();
    let parallel = sweep::run_sweep(&grid, sweep::default_threads()).unwrap();
    assert!(t0.elapsed().as_secs_f64() < 60.0, "256-DC sweep too slow");
    assert_eq!(serial.len(), 1);
    assert_eq!(parallel.len(), 1);
    assert_eq!(
        serial[0].ep.makespan.to_bits(),
        parallel[0].ep.makespan.to_bits(),
        "sweep results must not depend on worker count"
    );
    let o = &parallel[0];
    assert!(
        o.speedup > 0.9 && o.speedup < 4.0,
        "256-DC speedup {} outside the plausible band",
        o.speedup
    );
    // incremental engine vs reference oracle on the identical scenario
    let mut grid_ref = grid.clone();
    grid_ref.engine = hybrid_ep::netsim::RateMode::Reference;
    let reference = sweep::run_sweep(&grid_ref, 1).unwrap();
    let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-12);
    assert!(
        rel(o.ep.makespan, reference[0].ep.makespan) < 1e-9,
        "incremental EP makespan {} vs reference {}",
        o.ep.makespan,
        reference[0].ep.makespan
    );
    assert!(
        rel(o.hybrid.makespan, reference[0].hybrid.makespan) < 1e-9,
        "incremental hybrid makespan {} vs reference {}",
        o.hybrid.makespan,
        reference[0].hybrid.makespan
    );
}

#[test]
fn solver_partition_is_at_least_as_good_as_any_single_candidate() {
    // the deployed plan must not be beaten by any single-level-uniform rival
    let (cluster, w, routing) = ctx_parts(48.0, 2.0, exp::paper_cluster_l());
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let solved = HybridEp::with_migration();
    let t_solved = solved.iteration_time(&ctx);
    let scaling = cluster.multilevel().scaling().to_vec();
    let mut best_rival = f64::INFINITY;
    for s0 in [1usize, 2, 4] {
        for s1 in [1usize, 2, 4, 8] {
            if scaling[0] % s0 != 0 || scaling[1] % s1 != 0 {
                continue;
            }
            let rival = HybridEp {
                partition: Some(vec![s0, s1]),
                migration: Some(Default::default()),
            };
            best_rival = best_rival.min(rival.iteration_time(&ctx));
        }
    }
    assert!(
        t_solved <= best_rival * 1.15,
        "solver pick {t_solved} much worse than best grid rival {best_rival}"
    );
}

#[test]
fn skewed_routing_all_systems_still_conserve_tokens() {
    let cluster = exp::paper_cluster_m();
    let w = exp::workload_from_sizes(12e6, 2e6, 2, false);
    let routing = Routing::zipf(
        cluster.total_gpus(),
        cluster.total_gpus(),
        w.tokens_per_gpu,
        w.k,
        1.3,
        17,
    );
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let mut totals = Vec::new();
    for sys in comparison_set() {
        let dag = sys.build_iteration(&ctx);
        let total: f64 = dag
            .tasks
            .iter()
            .filter(|t| t.label == "expert")
            .map(|t| match t.kind {
                hybrid_ep::netsim::TaskKind::Compute { seconds, .. } => seconds,
                _ => 0.0,
            })
            .sum();
        totals.push((sys.name(), total));
    }
    let base = totals[0].1;
    for (name, t) in &totals {
        assert!(
            (t - base).abs() / base < 1e-6,
            "{name} computes {t} expert-seconds vs {base}"
        );
    }
}
