//! Golden-equivalence tests for the Plan-IR lowering pass.
//!
//! Each pre-refactor system hand-built its `Dag`; these tests keep verbatim
//! copies of those legacy builders and assert that the Plan-IR pipeline
//! (`System::plan_forward` → `plan::lower_forward`) reproduces the same
//! schedule observables on small test contexts: simulated **makespan**,
//! per-tag **traffic**, and total **expert compute**. Barrier placement may
//! differ (barriers are zero-cost), so equivalence is on observables, not
//! task-by-task identity.

use hybrid_ep::cluster::{presets, Multilevel};
use hybrid_ep::moe::routing::Placement;
use hybrid_ep::moe::{MoEWorkload, Routing};
use hybrid_ep::netsim::{Dag, Simulator, Tag, TaskId, TaskKind};
use hybrid_ep::systems::aggregate::AggregateHybrid;
use hybrid_ep::systems::ep::{Tutel, VanillaEp};
use hybrid_ep::systems::faster_moe::FasterMoe;
use hybrid_ep::systems::hybrid_ep::{HybridEp, MigrationCfg};
use hybrid_ep::systems::smart_moe::SmartMoe;
use hybrid_ep::systems::{SchedCtx, System};
use hybrid_ep::topology::DomainPartition;

// ---------------------------------------------------------------------------
// Legacy builders (verbatim pre-refactor DAG construction)
// ---------------------------------------------------------------------------

/// Pre-refactor `systems::ep::build_pipelined`.
fn legacy_pipelined(
    ctx: &SchedCtx,
    dag: &mut Dag,
    entry: &[TaskId],
    chunks: usize,
    placement: Option<&Placement>,
) -> Vec<TaskId> {
    let g = ctx.gpus();
    let default_placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
    let placement = placement.unwrap_or(&default_placement);
    let mut cur: Vec<TaskId> = entry.to_vec();

    for _layer in 0..ctx.workload.moe_layers {
        let pre: Vec<TaskId> = (0..g)
            .map(|i| dag.compute(i, ctx.pre_expert_secs(), vec![cur[i]], "pre_expert"))
            .collect();
        let mut exit_deps: Vec<Vec<TaskId>> = vec![Vec::new(); g];
        for _c in 0..chunks {
            let frac = 1.0 / chunks as f64;
            let mut arrive: Vec<Vec<TaskId>> = vec![Vec::new(); g];
            for i in 0..g {
                for j in 0..g {
                    let tokens = ctx.routing.tokens_to_gpu(i, j, placement) * frac;
                    if i == j || tokens <= 0.0 {
                        continue;
                    }
                    let t = dag.transfer(
                        i,
                        j,
                        ctx.token_bytes(tokens),
                        Tag::A2A,
                        vec![pre[i]],
                        "dispatch",
                    );
                    arrive[j].push(t);
                }
            }
            for j in 0..g {
                let total_tokens: f64 =
                    (0..g).map(|i| ctx.routing.tokens_to_gpu(i, j, placement)).sum::<f64>() * frac;
                let mut deps = arrive[j].clone();
                deps.push(pre[j]);
                let e = dag.compute(j, ctx.expert_secs(total_tokens), deps, "expert");
                for i in 0..g {
                    let tokens = ctx.routing.tokens_to_gpu(i, j, placement) * frac;
                    if i == j || tokens <= 0.0 {
                        exit_deps[i].push(e);
                        continue;
                    }
                    let t =
                        dag.transfer(j, i, ctx.token_bytes(tokens), Tag::A2A, vec![e], "combine");
                    exit_deps[i].push(t);
                }
            }
        }
        cur = (0..g)
            .map(|i| {
                let mut deps = std::mem::take(&mut exit_deps[i]);
                deps.push(pre[i]);
                dag.barrier(deps, "layer_end")
            })
            .collect();
    }
    cur
}

/// Pre-refactor `FasterMoe::build_forward`.
fn legacy_faster_moe(
    fm: &FasterMoe,
    ctx: &SchedCtx,
    dag: &mut Dag,
    entry: &[TaskId],
) -> Vec<TaskId> {
    let g = ctx.gpus();
    let placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
    let hot = fm.hot_experts(ctx);
    let is_hot = {
        let mut v = vec![false; placement.total_experts()];
        for &e in &hot {
            v[e] = true;
        }
        v
    };
    let pe = ctx.workload.pe_bytes();
    let mut cur: Vec<TaskId> = entry.to_vec();

    for _layer in 0..ctx.workload.moe_layers {
        let mut shadow_arrive: Vec<Vec<TaskId>> = vec![Vec::new(); g];
        for &e in &hot {
            let h = placement.host[e];
            for dst in 0..g {
                if dst == h {
                    continue;
                }
                let t = dag.transfer(h, dst, pe, Tag::AG, vec![cur[h]], "shadow");
                shadow_arrive[dst].push(t);
            }
        }
        let pre: Vec<TaskId> = (0..g)
            .map(|i| dag.compute(i, ctx.pre_expert_secs(), vec![cur[i]], "pre_expert"))
            .collect();

        let frac = 1.0 / fm.chunks as f64;
        let mut exit_deps: Vec<Vec<TaskId>> = vec![Vec::new(); g];
        for _c in 0..fm.chunks {
            let mut arrive: Vec<Vec<TaskId>> = vec![Vec::new(); g];
            for i in 0..g {
                for j in 0..g {
                    let tokens: f64 = placement
                        .experts_on(j)
                        .iter()
                        .filter(|&&e| !is_hot[e])
                        .map(|&e| ctx.routing.tokens[i][e])
                        .sum::<f64>()
                        * frac;
                    if i == j || tokens <= 0.0 {
                        continue;
                    }
                    let t = dag.transfer(
                        i,
                        j,
                        ctx.token_bytes(tokens),
                        Tag::A2A,
                        vec![pre[i]],
                        "dispatch",
                    );
                    arrive[j].push(t);
                }
            }
            for j in 0..g {
                let cold: f64 = (0..g)
                    .map(|i| {
                        placement
                            .experts_on(j)
                            .iter()
                            .filter(|&&e| !is_hot[e])
                            .map(|&e| ctx.routing.tokens[i][e])
                            .sum::<f64>()
                    })
                    .sum::<f64>()
                    * frac;
                let local_hot: f64 =
                    hot.iter().map(|&e| ctx.routing.tokens[j][e]).sum::<f64>() * frac;
                let mut deps = arrive[j].clone();
                deps.push(pre[j]);
                deps.extend(shadow_arrive[j].iter().copied());
                let ex = dag.compute(j, ctx.expert_secs(cold + local_hot), deps, "expert");
                for i in 0..g {
                    let tokens: f64 = placement
                        .experts_on(j)
                        .iter()
                        .filter(|&&e| !is_hot[e])
                        .map(|&e| ctx.routing.tokens[i][e])
                        .sum::<f64>()
                        * frac;
                    if i == j || tokens <= 0.0 {
                        exit_deps[i].push(ex);
                        continue;
                    }
                    let t =
                        dag.transfer(j, i, ctx.token_bytes(tokens), Tag::A2A, vec![ex], "combine");
                    exit_deps[i].push(t);
                }
            }
        }
        cur = (0..g)
            .map(|i| {
                let mut deps = std::mem::take(&mut exit_deps[i]);
                deps.push(pre[i]);
                dag.barrier(deps, "layer_end")
            })
            .collect();
    }
    cur
}

fn domain_coord(part: &DomainPartition, loc: &[usize], level: usize) -> usize {
    loc[level] / part.size_at(level)
}

fn diverge_level(
    ml: &Multilevel,
    part: &DomainPartition,
    loc_m: &[usize],
    loc_h: &[usize],
) -> Option<usize> {
    (0..ml.levels()).find(|&l| domain_coord(part, loc_m, l) != domain_coord(part, loc_h, l))
}

fn next_hop(
    ml: &Multilevel,
    part: &DomainPartition,
    loc_m: &[usize],
    loc_h: &[usize],
    level: usize,
) -> usize {
    let s = part.size_at(level);
    let mut loc = loc_m.to_vec();
    loc[level] = domain_coord(part, loc_h, level) * s + (loc_m[level] % s);
    ml.index_of(&loc)
}

/// Pre-refactor `HybridEp::build_forward` (explicit partition).
fn legacy_hybrid(
    ctx: &SchedCtx,
    dag: &mut Dag,
    entry: &[TaskId],
    part: &DomainPartition,
    mig: Option<&MigrationCfg>,
    pe_tx: f64,
) -> Vec<TaskId> {
    let g = ctx.gpus();
    let ml = ctx.cluster.multilevel();
    let nlevels = ml.levels();
    let placement = Placement::round_robin(g, ctx.workload.experts_per_gpu);
    let locs: Vec<Vec<usize>> = (0..g).map(|m| ml.locate(m)).collect();
    let pe_full = ctx.workload.pe_bytes();
    let n_exp = ctx.workload.experts_per_gpu;

    let mut holdings: Vec<usize> = vec![1; g];
    let mut ag_flows: Vec<Vec<(usize, usize, usize)>> = Vec::new();
    for l in (0..nlevels).rev() {
        let s = part.size_at(l);
        if s <= 1 {
            ag_flows.push(Vec::new());
            continue;
        }
        let mut phase = Vec::new();
        let mut new_holdings = holdings.clone();
        for m in 0..g {
            let dom = domain_coord(part, &locs[m], l);
            let off = locs[m][l] % s;
            for o in 0..s {
                if o == off {
                    continue;
                }
                let mut loc = locs[m].clone();
                loc[l] = dom * s + o;
                let peer = ml.index_of(&loc);
                phase.push((peer, m, holdings[peer]));
                new_holdings[m] += holdings[peer];
            }
        }
        holdings = new_holdings;
        ag_flows.push(phase);
    }

    let total_experts = placement.total_experts();
    let mut hold: Vec<Vec<f64>> = (0..g).map(|m| ctx.routing.tokens[m].clone()).collect();
    let mut disp_flows: Vec<Vec<(usize, usize, f64)>> = Vec::new();
    for l in 0..nlevels {
        let mut phase: Vec<(usize, usize, f64)> = Vec::new();
        let mut moves: Vec<(usize, usize, usize, f64)> = Vec::new();
        for m in 0..g {
            for e in 0..total_experts {
                let t = hold[m][e];
                if t <= 0.0 {
                    continue;
                }
                let h = placement.host[e];
                if diverge_level(&ml, part, &locs[m], &locs[h]) == Some(l) {
                    let j = next_hop(&ml, part, &locs[m], &locs[h], l);
                    moves.push((m, j, e, t));
                }
            }
        }
        let mut agg: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for &(m, j, e, t) in &moves {
            hold[m][e] -= t;
            hold[j][e] += t;
            *agg.entry((m, j)).or_default() += t;
        }
        phase.extend(agg.into_iter().map(|((m, j), t)| (m, j, t)));
        disp_flows.push(phase);
    }
    let compute_tokens: Vec<f64> = hold.iter().map(|h| h.iter().sum()).collect();

    let mut cur: Vec<TaskId> = entry.to_vec();
    for _layer in 0..ctx.workload.moe_layers {
        let enc: Vec<TaskId> = (0..g)
            .map(|m| match mig {
                Some(c) => dag.compute(
                    m,
                    c.encode_secs(pe_full) * n_exp as f64,
                    vec![cur[m]],
                    "sr_encode",
                ),
                None => cur[m],
            })
            .collect();

        let mut ag_done: Vec<Vec<TaskId>> = vec![Vec::new(); g];
        let mut ag_stage: Vec<TaskId> = enc.clone();
        for phase in &ag_flows {
            if phase.is_empty() {
                continue;
            }
            let mut next_stage = ag_stage.clone();
            let mut arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); g];
            for &(src, dst, nsrc) in phase {
                let bytes = nsrc as f64 * n_exp as f64 * pe_tx;
                let t = dag.transfer(src, dst, bytes, Tag::AG, vec![ag_stage[src]], "ag");
                arrivals[dst].push(t);
                ag_done[dst].push(t);
            }
            for m in 0..g {
                if !arrivals[m].is_empty() {
                    let mut deps = std::mem::take(&mut arrivals[m]);
                    deps.push(ag_stage[m]);
                    next_stage[m] = dag.barrier(deps, "ag_phase");
                }
            }
            ag_stage = next_stage;
        }

        let pre: Vec<TaskId> = (0..g)
            .map(|m| dag.compute(m, ctx.pre_expert_secs(), vec![cur[m]], "pre_expert"))
            .collect();

        let mut stage: Vec<TaskId> = pre.clone();
        for phase in &disp_flows {
            if phase.is_empty() {
                continue;
            }
            let mut arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); g];
            for &(src, dst, tokens) in phase {
                let t = dag.transfer(
                    src,
                    dst,
                    ctx.token_bytes(tokens),
                    Tag::A2A,
                    vec![stage[src]],
                    "dispatch",
                );
                arrivals[dst].push(t);
            }
            let mut next_stage = stage.clone();
            for m in 0..g {
                if !arrivals[m].is_empty() {
                    let mut deps = std::mem::take(&mut arrivals[m]);
                    deps.push(stage[m]);
                    next_stage[m] = dag.barrier(deps, "disp_phase");
                }
            }
            stage = next_stage;
        }

        let expert: Vec<TaskId> = (0..g)
            .map(|m| {
                let mut secs = ctx.expert_secs(compute_tokens[m]);
                if let Some(c) = mig {
                    let gathered = (holdings[m] - 1) as f64 * n_exp as f64;
                    secs += gathered * c.decode_secs(pe_full);
                }
                let mut deps = vec![stage[m], pre[m]];
                deps.append(&mut ag_done[m].clone());
                dag.compute(m, secs, deps, "expert")
            })
            .collect();

        let mut stage: Vec<TaskId> = expert.clone();
        for phase in disp_flows.iter().rev() {
            if phase.is_empty() {
                continue;
            }
            let mut arrivals: Vec<Vec<TaskId>> = vec![Vec::new(); g];
            for &(src, dst, tokens) in phase {
                let t = dag.transfer(
                    dst,
                    src,
                    ctx.token_bytes(tokens),
                    Tag::A2A,
                    vec![stage[dst]],
                    "combine",
                );
                arrivals[src].push(t);
            }
            let mut next_stage = stage.clone();
            for m in 0..g {
                if !arrivals[m].is_empty() {
                    let mut deps = std::mem::take(&mut arrivals[m]);
                    deps.push(stage[m]);
                    next_stage[m] = dag.barrier(deps, "comb_phase");
                }
            }
            stage = next_stage;
        }

        cur = (0..g).map(|m| dag.barrier(vec![stage[m], expert[m]], "layer_end")).collect();
    }
    cur
}

/// Pre-refactor `AggregateHybrid::build_forward`.
fn legacy_aggregate(
    sys: &AggregateHybrid,
    ctx: &SchedCtx,
    dag: &mut Dag,
    entry: &[TaskId],
) -> Vec<TaskId> {
    let g = ctx.gpus();
    assert!(g % sys.s_ed == 0, "S_ED must divide G");
    let w = ctx.workload;
    let p = sys.p(g);
    let d = w.d_bytes() * w.k as f64;
    let pe = sys.pe_tx_bytes.unwrap_or_else(|| w.pe_bytes());
    let a2a_bytes = p * d * (g as f64 - 1.0) / g as f64;
    let ag_bytes = (sys.s_ed as f64 - 1.0) * w.experts_per_gpu as f64 * pe;
    let expert_secs = ctx.expert_secs((w.tokens_per_gpu * w.k) as f64);

    let domains = g / sys.s_ed;
    let a2a_setup =
        sys.msg_overhead_secs * if sys.s_ed == 1 { (g - 1) as f64 } else { (domains - 1) as f64 };
    let ag_setup = sys.msg_overhead_secs * (sys.s_ed - 1) as f64;

    let mut cur: Vec<TaskId> = entry.to_vec();
    for _layer in 0..w.moe_layers {
        let ag: Vec<Option<TaskId>> = (0..g)
            .map(|i| {
                if ag_bytes > 0.0 {
                    let dom = i / sys.s_ed;
                    let off = i % sys.s_ed;
                    let dst = dom * sys.s_ed + (off + 1) % sys.s_ed;
                    let setup = dag.compute(i, ag_setup, vec![cur[i]], "ag_setup");
                    Some(dag.transfer(i, dst, ag_bytes, Tag::AG, vec![setup], "ag"))
                } else {
                    None
                }
            })
            .collect();
        let pre: Vec<TaskId> = (0..g)
            .map(|i| dag.compute(i, ctx.pre_expert_secs(), vec![cur[i]], "pre_expert"))
            .collect();
        let disp: Vec<Option<TaskId>> = (0..g)
            .map(|i| {
                if a2a_bytes > 0.0 && domains > 1 {
                    let dom = i / sys.s_ed;
                    let off = i % sys.s_ed;
                    let dst = ((dom + 1) % domains) * sys.s_ed + off;
                    let setup = dag.compute(i, a2a_setup, vec![pre[i]], "a2a_setup");
                    Some(dag.transfer(i, dst, a2a_bytes, Tag::A2A, vec![setup], "dispatch"))
                } else {
                    None
                }
            })
            .collect();
        let expert: Vec<TaskId> = (0..g)
            .map(|i| {
                let mut deps = vec![pre[i]];
                if let Some(t) = ag[i] {
                    deps.push(t);
                }
                if let Some(t) = disp[i] {
                    deps.push(t);
                }
                dag.compute(i, expert_secs, deps, "expert")
            })
            .collect();
        let comb: Vec<TaskId> = (0..g)
            .map(|i| {
                if a2a_bytes > 0.0 && domains > 1 {
                    let dom = i / sys.s_ed;
                    let off = i % sys.s_ed;
                    let dst = ((dom + domains - 1) % domains) * sys.s_ed + off;
                    dag.transfer(i, dst, a2a_bytes, Tag::A2A, vec![expert[i]], "combine")
                } else {
                    expert[i]
                }
            })
            .collect();
        cur = (0..g).map(|i| dag.barrier(vec![comb[i], expert[i]], "layer_end")).collect();
    }
    cur
}

// ---------------------------------------------------------------------------
// Equivalence harness
// ---------------------------------------------------------------------------

struct Observables {
    makespan: f64,
    a2a: f64,
    ag: f64,
    expert_secs: f64,
    a2a_freq: usize,
    ag_freq: usize,
}

fn observe(cluster: &hybrid_ep::cluster::ClusterSpec, dag: &Dag) -> Observables {
    let expert_secs = dag
        .tasks
        .iter()
        .filter(|t| t.label == "expert")
        .map(|t| match t.kind {
            TaskKind::Compute { seconds, .. } => seconds,
            _ => 0.0,
        })
        .sum();
    Observables {
        makespan: Simulator::new(cluster).run(dag).makespan,
        a2a: dag.traffic_by_tag(Tag::A2A),
        ag: dag.traffic_by_tag(Tag::AG),
        expert_secs,
        a2a_freq: dag.frequency_by_tag(Tag::A2A),
        ag_freq: dag.frequency_by_tag(Tag::AG),
    }
}

fn forward_dag(
    ctx: &SchedCtx,
    build: impl FnOnce(&mut Dag, &[TaskId]) -> Vec<TaskId>,
) -> Dag {
    let mut dag = Dag::new();
    let start = dag.barrier(vec![], "iter_start");
    let entry: Vec<TaskId> = (0..ctx.gpus()).map(|_| start).collect();
    let exit = build(&mut dag, &entry);
    dag.barrier(exit, "iter_end");
    dag
}

fn assert_equivalent(name: &str, cluster: &hybrid_ep::cluster::ClusterSpec, old: &Dag, new: &Dag) {
    let a = observe(cluster, old);
    let b = observe(cluster, new);
    let rel = |x: f64, y: f64| (x - y).abs() / (1.0 + x.abs().max(y.abs()));
    assert!(
        rel(a.makespan, b.makespan) < 1e-6,
        "{name}: makespan diverged: legacy {} vs lowered {}",
        a.makespan,
        b.makespan
    );
    assert!(rel(a.a2a, b.a2a) < 1e-9, "{name}: A2A traffic {} vs {}", a.a2a, b.a2a);
    assert!(rel(a.ag, b.ag) < 1e-9, "{name}: AG traffic {} vs {}", a.ag, b.ag);
    assert!(
        rel(a.expert_secs, b.expert_secs) < 1e-9,
        "{name}: expert compute {} vs {}",
        a.expert_secs,
        b.expert_secs
    );
    assert_eq!(a.a2a_freq, b.a2a_freq, "{name}: A2A transfer count");
    assert_eq!(a.ag_freq, b.ag_freq, "{name}: AG transfer count");
}

fn small_parts(zipf: bool) -> (hybrid_ep::cluster::ClusterSpec, MoEWorkload, Routing) {
    let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
    let w = MoEWorkload {
        tokens_per_gpu: 512,
        hidden: 256,
        ffn: 512,
        experts_per_gpu: 2,
        k: 2,
        moe_layers: 2,
        pre_blocks: 1,
        backward: false,
    };
    let g = cluster.total_gpus();
    let routing = if zipf {
        Routing::zipf(g, g * w.experts_per_gpu, w.tokens_per_gpu, w.k, 1.4, 23)
    } else {
        Routing::uniform(g, g * w.experts_per_gpu, w.tokens_per_gpu, w.k)
    };
    (cluster, w, routing)
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn vanilla_ep_and_tutel_lower_to_legacy_schedules() {
    for zipf in [false, true] {
        let (cluster, w, routing) = small_parts(zipf);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let old = forward_dag(&ctx, |dag, entry| legacy_pipelined(&ctx, dag, entry, 1, None));
        let new = forward_dag(&ctx, |dag, entry| VanillaEp.build_forward(&ctx, dag, entry));
        assert_equivalent("VanillaEP", &cluster, &old, &new);

        let old = forward_dag(&ctx, |dag, entry| legacy_pipelined(&ctx, dag, entry, 4, None));
        let new =
            forward_dag(&ctx, |dag, entry| Tutel { chunks: 4 }.build_forward(&ctx, dag, entry));
        assert_equivalent("Tutel", &cluster, &old, &new);
    }
}

#[test]
fn smart_moe_lowers_to_legacy_schedule() {
    let (cluster, w, routing) = small_parts(true);
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let sm = SmartMoe::default();
    let placement = sm.search_placement(&ctx);
    let old = forward_dag(&ctx, |dag, entry| {
        legacy_pipelined(&ctx, dag, entry, sm.chunks, Some(&placement))
    });
    let new = forward_dag(&ctx, |dag, entry| sm.build_forward(&ctx, dag, entry));
    assert_equivalent("SmartMoE", &cluster, &old, &new);
}

#[test]
fn faster_moe_lowers_to_legacy_schedule() {
    let (cluster, w, routing) = small_parts(true);
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let fm = FasterMoe::default();
    assert!(!fm.hot_experts(&ctx).is_empty(), "zipf context must shadow something");
    let old = forward_dag(&ctx, |dag, entry| legacy_faster_moe(&fm, &ctx, dag, entry));
    let new = forward_dag(&ctx, |dag, entry| fm.build_forward(&ctx, dag, entry));
    assert_equivalent("FasterMoE", &cluster, &old, &new);
}

#[test]
fn hybrid_ep_lowers_to_legacy_schedule_across_partitions() {
    for zipf in [false, true] {
        let (cluster, w, routing) = small_parts(zipf);
        let ctx = SchedCtx::new(&cluster, &w, &routing);
        let ml = cluster.multilevel();
        for sizes in [vec![1, 1], vec![1, 2], vec![2, 1], vec![1, 4], vec![2, 4]] {
            for with_mig in [false, true] {
                let mig = with_mig.then(MigrationCfg::default);
                let sys = HybridEp { partition: Some(sizes.clone()), migration: mig };
                let part = DomainPartition::new(&ml, sizes.clone()).unwrap();
                let pe_tx = sys.pe_tx_bytes(&ctx);
                let old = forward_dag(&ctx, |dag, entry| {
                    legacy_hybrid(&ctx, dag, entry, &part, mig.as_ref(), pe_tx)
                });
                let new = forward_dag(&ctx, |dag, entry| sys.build_forward(&ctx, dag, entry));
                assert_equivalent(
                    &format!("HybridEP {sizes:?} mig={with_mig} zipf={zipf}"),
                    &cluster,
                    &old,
                    &new,
                );
            }
        }
    }
}

#[test]
fn aggregate_lowers_to_legacy_schedule() {
    let cluster = presets::flat_dcs(12, 5.0);
    let w = MoEWorkload {
        tokens_per_gpu: 2048,
        hidden: 512,
        ffn: 1024,
        experts_per_gpu: 1,
        k: 2,
        moe_layers: 2,
        pre_blocks: 1,
        backward: false,
    };
    let routing = Routing::uniform(1, 1, 1, 1); // aggregate schedules ignore it
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    for sys in [
        AggregateHybrid::ep(),
        AggregateHybrid::hybrid(3, w.pe_bytes() / 50.0),
        AggregateHybrid::hybrid(12, w.pe_bytes() / 50.0),
    ] {
        let old = forward_dag(&ctx, |dag, entry| legacy_aggregate(&sys, &ctx, dag, entry));
        let new = forward_dag(&ctx, |dag, entry| sys.build_forward(&ctx, dag, entry));
        assert_equivalent(&format!("Aggregate s_ed={}", sys.s_ed), &cluster, &old, &new);
    }
}

/// Overlap-refactor acceptance: every system's planner still emits pure
/// `Sync::Bulk` phases (overlap is opt-in per phase at lowering, never a
/// planner default), and the explicit trivial 4D config — `pp = 1`, one
/// microbatch — reproduces the legacy schedule bit for bit with the overlap
/// flag in either position.
#[test]
fn bulk_sync_and_trivial_pipeline_pin_legacy_equivalence() {
    use hybrid_ep::cluster::ParallelismConfig;
    use hybrid_ep::plan::Sync;
    use hybrid_ep::systems::comparison_set;
    let (cluster, mut w, routing) = small_parts(true);
    w.backward = true;
    let plain = SchedCtx::new(&cluster, &w, &routing);
    let cfg = ParallelismConfig::new_4d(&cluster, 1, 1, 1, 1).unwrap();
    assert!(cfg.is_identity(), "pp = 1, tp = 1, dp = 1, mb = 1 is the identity");
    for sys in comparison_set() {
        let plan = sys.plan_forward(&plain);
        assert!(plan.pipeline.is_none(), "{}: identity plan carries a pipeline", sys.name());
        for layer in &plan.layers {
            let phases = layer
                .migrate
                .phases
                .iter()
                .chain(layer.rounds.iter().flat_map(|r| r.dispatch.iter()))
                .chain(layer.tp_sync.iter());
            for p in phases {
                assert_eq!(
                    p.sync,
                    Sync::Bulk,
                    "{}: planner emitted a non-Bulk phase {:?}",
                    sys.name(),
                    p.label
                );
            }
        }
        let base = Simulator::new(&cluster).run(&sys.build_iteration(&plain)).makespan;
        for overlap in [true, false] {
            let mut ctx = SchedCtx::new(&cluster, &w, &routing).with_parallelism(cfg);
            ctx.pp_overlap = overlap;
            let got = Simulator::new(&cluster).run(&sys.build_iteration(&ctx)).makespan;
            assert_eq!(
                base.to_bits(),
                got.to_bits(),
                "{} (pp_overlap = {overlap}): trivial pipeline config diverged",
                sys.name()
            );
        }
    }
}

/// Joint-parallelism acceptance: with `tp = 1, dp = 1` every system's Plan
/// IR and simulated makespan are identical to the pre-config pipeline, bit
/// for bit (the config machinery must be a pure pass-through).
#[test]
fn identity_parallelism_reproduces_plans_bit_for_bit() {
    use hybrid_ep::cluster::ParallelismConfig;
    use hybrid_ep::plan::parallel::planned_forward;
    use hybrid_ep::systems::comparison_set;
    for zipf in [false, true] {
        let (cluster, mut w, routing) = small_parts(zipf);
        w.backward = true; // cover the DDP epilogue path too
        let plain = SchedCtx::new(&cluster, &w, &routing);
        let explicit = SchedCtx::new(&cluster, &w, &routing)
            .with_parallelism(ParallelismConfig::identity(cluster.total_gpus()));
        for sys in comparison_set() {
            let a = sys.plan_forward(&plain);
            let b = planned_forward(sys.as_ref(), &explicit);
            assert_eq!(a, b, "{}: Plan IR diverged under the identity config", sys.name());
            let ta = Simulator::new(&cluster).run(&sys.build_iteration(&plain)).makespan;
            let tb = Simulator::new(&cluster).run(&sys.build_iteration(&explicit)).makespan;
            assert_eq!(ta.to_bits(), tb.to_bits(), "{}: makespan bits diverged", sys.name());
        }
    }
}
