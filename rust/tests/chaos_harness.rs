//! Chaos-harness integration: real concurrent runs under seeded fault
//! schedules, checked against the fault-free reference.
//!
//! Acceptance gates exercised here:
//! * soak over seeded random schedules — every run finishes (watchdog-
//!   bounded, never wedges) and its committed loss history matches the
//!   fault-free reference (no lost or double-counted optimizer steps);
//! * identical seeds render byte-identical event logs;
//! * elastic recovery strictly beats restart-from-scratch on late kills;
//! * fault-free runs report zero false lease expiries and bounded
//!   heartbeat overhead;
//! * replica failover skips the checkpoint rollback entirely;
//! * a killed node revives and rejoins at its scheduled commit.

use std::path::PathBuf;

use hybrid_ep::plan::replanner::elastic::RecoveryMode;
use hybrid_ep::runtime::chaos::{ChaosCfg, ChaosSchedule, Event};
use hybrid_ep::runtime::harness::{reference_losses, run, HarnessCfg};

fn store_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hybrid_ep_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Committed histories must agree with the reference up to f64 summation
/// order across reporting shards (~1e-16 relative; 1e-9 is generous).
fn assert_losses_match(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: committed history length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-9 * w.abs().max(1.0),
            "{ctx}: iteration {i} loss {g} diverged from reference {w}"
        );
    }
}

#[test]
fn fault_free_run_commits_everything_with_zero_false_expiries() {
    let cfg = HarnessCfg::quick(4, 10, 11, store_dir("clean"));
    let r = run(&cfg, &ChaosSchedule::none(11)).expect("clean run");
    assert_eq!(r.committed, 10);
    assert_losses_match(&r.losses, &reference_losses(&cfg), "clean");
    assert_eq!(r.lease_expiries, 0, "false expiry on a healthy run");
    assert_eq!(r.recoveries, 0);
    assert_eq!(r.epochs, 1);
    assert_eq!(r.executed_iters, 4 * 10, "clean runs execute each iteration exactly once");
    assert_eq!(r.checkpoints, 2, "boundaries 4 and 8");
    assert!(r.heartbeats > 0);
    assert!(
        (r.heartbeat_bytes as f64) < 0.2 * r.data_bytes as f64,
        "heartbeat overhead {} out of bound vs data {}",
        r.heartbeat_bytes,
        r.data_bytes
    );
    assert!(matches!(r.log.events.last(), Some(Event::Finished { committed: 10, .. })));
}

#[test]
fn elastic_recovery_restores_last_checkpoint_and_conserves_losses() {
    let cfg = HarnessCfg::quick(4, 14, 23, store_dir("elastic"));
    let r = run(&cfg, &ChaosSchedule::none(23).kill(2, 9)).expect("elastic run");
    assert_eq!(r.committed, 14);
    assert_losses_match(&r.losses, &reference_losses(&cfg), "elastic");
    assert_eq!(r.lease_expiries, 1);
    assert_eq!(r.recoveries, 1);
    assert_eq!(r.restores, 1, "must restore from the boundary-8 manifest");
    assert_eq!(r.epochs, 2);
    assert!(r.redone_iters >= 1, "rollback re-walks at least one iteration");
    let text = r.log.to_text();
    assert!(text.contains("lease-expired node=2 done=9"), "{text}");
    assert!(text.contains("restored_from=Some(8)"), "{text}");
    assert!(!r.replans.is_empty(), "recovery must re-solve the layout");
    assert_eq!(r.replans[0].survivors, 3);
    assert!(!r.recovery_secs.is_empty());
}

#[test]
fn elastic_strictly_beats_static_restart_on_a_late_kill() {
    let sched = ChaosSchedule::none(5).kill(1, 21);
    let e_cfg = HarnessCfg::quick(4, 24, 5, store_dir("beats_elastic"));
    let e = run(&e_cfg, &sched).expect("elastic");
    let mut s_cfg = HarnessCfg::quick(4, 24, 5, store_dir("beats_static"));
    s_cfg.recovery = RecoveryMode::StaticRestart;
    let s = run(&s_cfg, &sched).expect("static restart");
    assert_eq!(e.committed, 24);
    assert_eq!(s.committed, 24);
    assert_losses_match(&e.losses, &reference_losses(&e_cfg), "elastic");
    assert_losses_match(&s.losses, &reference_losses(&s_cfg), "static");
    assert_eq!(e.restores, 1);
    assert_eq!(s.restores, 0);
    assert!(s.log.to_text().contains("mode=StaticRestart"), "static restart must be logged");
    assert!(
        e.redone_iters < s.redone_iters,
        "elastic redid {} iterations, static only {}",
        e.redone_iters,
        s.redone_iters
    );
    assert!(
        e.executed_iters < s.executed_iters,
        "elastic executed {} worker-iterations, static only {}",
        e.executed_iters,
        s.executed_iters
    );
    assert!(
        e.wall_secs < s.wall_secs,
        "elastic took {:.3}s, not faster than static {:.3}s",
        e.wall_secs,
        s.wall_secs
    );
}

#[test]
fn replica_failover_skips_rollback_when_a_replica_covers() {
    let mut cfg = HarnessCfg::quick(4, 14, 31, store_dir("failover"));
    cfg.recovery = RecoveryMode::ReplicaFailover;
    let r = run(&cfg, &ChaosSchedule::none(31).kill(3, 9)).expect("failover run");
    assert_eq!(r.committed, 14);
    assert_losses_match(&r.losses, &reference_losses(&cfg), "failover");
    assert_eq!(r.restores, 0, "failover must not touch the checkpoint store");
    assert!(r.redone_iters <= 2, "no rollback: redid {}", r.redone_iters);
    let text = r.log.to_text();
    assert!(text.contains("mode=ReplicaFailover"), "{text}");
    assert!(text.contains("restored_from=None"), "{text}");
}

#[test]
fn killed_node_revives_and_rejoins_at_the_scheduled_commit() {
    let cfg = HarnessCfg::quick(4, 16, 47, store_dir("revive"));
    let sched = ChaosSchedule::none(47).kill(2, 6).reviving_at(10);
    let r = run(&cfg, &sched).expect("revival run");
    assert_eq!(r.committed, 16);
    assert_losses_match(&r.losses, &reference_losses(&cfg), "revival");
    assert_eq!(r.recoveries, 2, "one eviction + one grow");
    assert_eq!(r.epochs, 3);
    let text = r.log.to_text();
    assert!(text.contains("joined=[2]"), "{text}");
    assert!(text.contains("resume_from=10"), "{text}");
}

#[test]
fn identical_seeds_produce_byte_identical_event_logs() {
    for seed in [3u64, 9, 17, 29] {
        let chaos = ChaosCfg {
            seed,
            faults: 2,
            drop_p: 0.05,
            delay_p: 0.10,
            max_delay_sim_secs: 0.05,
            revive: seed % 2 == 1,
        };
        let cfg_a = HarnessCfg::quick(4, 12, seed, store_dir(&format!("det_{seed}_a")));
        let sched =
            ChaosSchedule::random(4, 12, cfg_a.lease.timeout_secs(), &chaos).unwrap();
        let a = run(&cfg_a, &sched).expect("run a");
        let cfg_b = HarnessCfg::quick(4, 12, seed, store_dir(&format!("det_{seed}_b")));
        let b = run(&cfg_b, &sched).expect("run b");
        assert_eq!(a.log.to_text(), b.log.to_text(), "seed {seed}: event logs diverged");
    }
}

#[test]
fn soak_sixteen_seeded_schedules_never_wedge_and_conserve_losses() {
    for seed in 0..16u64 {
        let cfg = HarnessCfg::quick(4, 10, seed, store_dir(&format!("soak_{seed}")));
        let chaos = ChaosCfg {
            seed,
            faults: 2,
            drop_p: 0.05,
            delay_p: 0.10,
            max_delay_sim_secs: 0.05,
            revive: seed % 3 == 0,
        };
        let sched =
            ChaosSchedule::random(4, 10, cfg.lease.timeout_secs(), &chaos).unwrap();
        let r = run(&cfg, &sched)
            .unwrap_or_else(|e| panic!("seed {seed} wedged or failed: {e:#}"));
        assert_eq!(r.committed, 10, "seed {seed}");
        assert_losses_match(&r.losses, &reference_losses(&cfg), &format!("soak seed {seed}"));
        assert_eq!(
            r.log.count(|e| matches!(e, Event::Finished { .. })),
            1,
            "seed {seed}: exactly one Finished event"
        );
    }
}

#[test]
fn watchdog_bounds_the_run_instead_of_wedging() {
    let mut cfg = HarnessCfg::quick(4, 400, 3, store_dir("watchdog"));
    cfg.watchdog_secs = 0.2; // far too tight for 400 iterations
    let t0 = std::time::Instant::now();
    let err = run(&cfg, &ChaosSchedule::none(3)).expect_err("must abort, not hang");
    assert!(format!("{err:#}").contains("watchdog"), "{err:#}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "teardown after the watchdog abort is not bounded"
    );
}
