//! Planner: describe any hierarchical cluster in TOML, get the model-guided
//! HybridEP deployment plan and the predicted speedup over vanilla EP.
//!
//!   cargo run --release --example planner -- --config configs/cluster_4dc.toml \
//!       --data-mb 48 --expert-mb 8 --cr 50

use anyhow::Result;
use hybrid_ep::cluster::ClusterSpec;
use hybrid_ep::model::solver;
use hybrid_ep::moe::{GpuSpec, Routing};
use hybrid_ep::report::experiments::workload_from_sizes;
use hybrid_ep::report::Table;
use hybrid_ep::systems::hybrid_ep::HybridEp;
use hybrid_ep::systems::{ep, SchedCtx, System};
use hybrid_ep::topology::Topology;
use hybrid_ep::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let cluster = match args.get("config") {
        Some(path) => {
            let v = hybrid_ep::config::load(std::path::Path::new(path))?;
            ClusterSpec::from_config(&v)?
        }
        None => hybrid_ep::report::experiments::paper_cluster_l(),
    };
    let d = args.f64_or("data-mb", 48.0)? * 1e6;
    let e = args.f64_or("expert-mb", 8.0)? * 1e6;
    let cr = args.f64_or("cr", 50.0)?;
    let layers = args.usize_or("layers", 12)?;

    let w = workload_from_sizes(d, e, layers, true);
    let gpu = GpuSpec::a800();
    let input = w.plan_input(&gpu, cluster.total_gpus(), w.pe_bytes() / cr);
    let plan = solver::plan_multilevel(&cluster, &input)?;

    println!(
        "cluster {:?}: {} GPUs across {} levels",
        cluster.name,
        cluster.total_gpus(),
        cluster.levels.len()
    );
    let mut t = Table::new("Plan", &["level", "name", "fanout", "bw", "S_ED", "p", "case"]);
    for (lp, spec) in plan.levels.iter().zip(&cluster.levels) {
        t.row(vec![
            lp.level.to_string(),
            spec.name.clone(),
            spec.fanout.to_string(),
            format!("{:.1} Gbps", spec.bandwidth * 8.0 / 1e9),
            lp.s_ed.to_string(),
            format!("{:.3}", lp.p),
            format!("{:?}", lp.case),
        ]);
    }
    t.print();

    // validate the plan end-to-end on the simulator
    let routing = Routing::uniform(
        cluster.total_gpus(),
        cluster.total_gpus() * w.experts_per_gpu,
        w.tokens_per_gpu,
        w.k,
    );
    let ctx = SchedCtx::new(&cluster, &w, &routing);
    let ep_time = ep::Tutel::default().iteration_time(&ctx);
    let hybrid = HybridEp {
        partition: Some(plan.partition_sizes.clone()),
        migration: Some(Default::default()),
    };
    let hy_time = hybrid.iteration_time(&ctx);
    println!(
        "simulated iteration: Tutel-EP {} vs HybridEP {} → {:.2}× speedup",
        hybrid_ep::util::fmt_secs(ep_time),
        hybrid_ep::util::fmt_secs(hy_time),
        ep_time / hy_time
    );

    let topo = Topology::build(cluster.multilevel(), hybrid.resolve_partition(&ctx));
    let f = topo.frequency();
    println!("topology: {} A2A pairs, {} AG pairs (Table VII semantics)", f.a2a, f.ag);
    Ok(())
}
