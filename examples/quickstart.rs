//! Quickstart: the three layers in one page.
//!
//! 1. Load an AOT Pallas artifact and run the expert FFN on the PJRT runtime.
//! 2. Plan a cross-DC deployment with the stream model.
//! 3. Inspect the resulting communication topology.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;
use hybrid_ep::cluster::Multilevel;
use hybrid_ep::model::solver;
use hybrid_ep::model::StreamConfig;
use hybrid_ep::runtime::exec::literal_f32;
use hybrid_ep::runtime::{Artifacts, Engine};
use hybrid_ep::topology::{DomainPartition, Topology};
use hybrid_ep::util::rng::Rng;

fn main() -> Result<()> {
    // --- 1. Layer-1/2: run the Pallas expert-FFN kernel through PJRT -------
    let arts = Artifacts::discover()?;
    let mut engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let demo = arts.demo_config()?;
    let (e, h, m) = (
        demo.req("e")?.as_usize()?,
        demo.req("h")?.as_usize()?,
        demo.req("m")?.as_usize()?,
    );
    let c = arts.manifest.at(&["demo", "capacity"])?.as_usize()?;
    let ffn = engine.load(&arts.demo_entry("expert_ffn")?)?;
    let mut rng = Rng::new(0);
    let mut rand = |n: usize| (0..n).map(|_| rng.normal() as f32 * 0.1).collect::<Vec<_>>();
    let x = rand(e * c * h);
    let w1 = rand(e * h * m);
    let w2 = rand(e * m * h);
    let t0 = std::time::Instant::now();
    let out = ffn.run(&[
        literal_f32(&x, &[e, c, h])?,
        literal_f32(&w1, &[e, h, m])?,
        literal_f32(&w2, &[e, m, h])?,
    ])?;
    println!(
        "expert_ffn (Pallas, AOT): [{e}, {c}, {h}] in {:.2} ms → output sum {:.4}",
        t0.elapsed().as_secs_f64() * 1e3,
        out[0].to_vec::<f32>()?.iter().sum::<f32>()
    );

    // --- 2. Layer-3: plan a 4-DC deployment with the stream model ----------
    let stream = StreamConfig {
        g: 4,                          // 4 DCs
        d_bytes: 48e6,                 // 48 MB activations leave each DC
        pe_bytes: 8e6 / 50.0,          // 8 MB experts, SR-compressed 50×
        n_experts: 2,
        bandwidth: 10e9 / 8.0,         // 10 Gbps inter-DC
        lat_pe: 2e-3,
        lat_ep: 0.5e-3,
    };
    let sol = solver::solve_continuous(&stream);
    let grid = solver::solve_grid(&stream);
    println!(
        "\nstream model: continuous p* = {:.3} ({:?}), deployable S_ED = {} (p = {:.2})",
        sol.p_star, sol.case, grid.s_ed, grid.p
    );
    println!(
        "predicted: EP = {:.1} ms vs HybridEP = {:.1} ms ({:.2}× speedup)",
        stream.lat_final(1.0) * 1e3,
        grid.latency * 1e3,
        stream.lat_final(1.0) / grid.latency
    );

    // --- 3. The communication topology it implies ---------------------------
    let ml = Multilevel::new(vec![4])?;
    let part = DomainPartition::new(&ml, vec![grid.s_ed])?;
    let topo = Topology::build(ml, part);
    let f = topo.frequency();
    println!("\ntopology: {} A2A pairs, {} AG pairs", f.a2a, f.ag);
    for gpu in 0..4 {
        println!(
            "  DC {gpu}: expert group {:?}, A2A peers {:?}",
            topo.expert_group(gpu),
            topo.a2a_peers(gpu).collect::<Vec<_>>()
        );
    }
    Ok(())
}
