//! End-to-end training driver (DESIGN.md "End-to-end validation").
//!
//! Trains an MoE transformer LM on the synthetic Markov corpus for a few
//! hundred steps, with the Rust coordinator repeatedly executing the AOT
//! `train_step` artifact (fwd + bwd + Adam + Pallas expert kernels in one
//! HLO — Python never runs). Logs the loss curve and writes it to
//! `train_e2e_<profile>.csv` for EXPERIMENTS.md.
//!
//!   cargo run --release --example train_e2e                     # ~20M params
//!   cargo run --release --example train_e2e -- --profile large  # ~100M params
//!   cargo run --release --example train_e2e -- --fig14          # Fig. 14 loss comparison
//!
//! Flags: --profile test|small|large  --steps N  --seed S  --fig14 [--cr CR]

use std::io::Write as _;

use anyhow::Result;
use hybrid_ep::runtime::{Artifacts, Engine};
use hybrid_ep::trainer::{Compression, Trainer};
use hybrid_ep::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let arts = Artifacts::discover()?;
    let profile = args.get_or("profile", "small");
    let steps = args.usize_or("steps", 300)?;
    let seed = args.usize_or("seed", 42)? as u64;

    if args.bool("fig14") {
        return fig14(&arts, profile, args.usize_or("steps", 200)?, args.usize_or("cr", 50)?, seed);
    }

    let mut engine = Engine::cpu()?;
    let mut t = Trainer::new(&mut engine, &arts, profile, seed)?;
    println!(
        "profile {profile}: {} parameters, {} experts × {} layers, vocab {}, corpus floor {:.3} nats",
        t.profile.param_count, t.profile.e, t.profile.n_layers, t.profile.vocab,
        t.corpus_entropy()
    );
    let t0 = std::time::Instant::now();
    t.train(steps, 10)?;
    let wall = t0.elapsed().as_secs_f64();
    let toks: usize = t.history.iter().map(|m| m.tokens).sum();
    println!(
        "\ntrained {steps} steps ({toks} tokens) in {wall:.1}s — {:.0} tok/s, loss {:.4} → {:.4}",
        toks as f64 / wall,
        t.losses()[0],
        t.recent_loss(10)
    );

    let path = format!("train_e2e_{profile}.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "step,loss,step_secs")?;
    for m in &t.history {
        writeln!(f, "{},{},{}", m.step, m.loss, m.step_secs)?;
    }
    println!("loss curve written to {path}");
    Ok(())
}

/// Fig. 14: loss under SR compression with vs without the shared expert.
fn fig14(arts: &Artifacts, profile: &str, steps: usize, cr: usize, seed: u64) -> Result<()> {
    println!("Fig. 14 — loss analysis at CR = {cr}× ({steps} steps, profile {profile})");
    let variants: [(&str, Compression); 3] = [
        ("baseline", Compression::None),
        ("HybridEP w/ S", Compression::WithShared { cr }),
        ("HybridEP w/o S", Compression::WithoutShared { cr }),
    ];
    let mut curves: Vec<(String, Vec<f32>)> = Vec::new();
    for (name, comp) in variants {
        let mut engine = Engine::cpu()?;
        let mut t = Trainer::new(&mut engine, arts, profile, seed)?;
        t.compression = comp;
        let t0 = std::time::Instant::now();
        t.train(steps, 0)?;
        println!(
            "  {name:<16} final loss (avg last 10): {:.4}   [{:.1}s]",
            t.recent_loss(10),
            t0.elapsed().as_secs_f64()
        );
        curves.push((name.to_string(), t.losses()));
    }
    let path = format!("fig14_loss_{profile}.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "step,{}", curves.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>().join(","))?;
    for i in 0..steps {
        let row: Vec<String> = curves.iter().map(|(_, l)| l[i].to_string()).collect();
        writeln!(f, "{},{}", i, row.join(","))?;
    }
    println!("curves written to {path}");
    let base = curves[0].1.iter().rev().take(10).sum::<f32>() / 10.0;
    let ws = curves[1].1.iter().rev().take(10).sum::<f32>() / 10.0;
    let wos = curves[2].1.iter().rev().take(10).sum::<f32>() / 10.0;
    // paper ordering: w/S tracks (or beats) the baseline; w/o S is never
    // better than w/S and degrades when experts carry real capacity
    let ok = ws <= base + 0.05 && wos + 1e-4 >= ws;
    println!(
        "\npaper shape check: w/S ({ws:.3}) ≤ baseline ({base:.3}) + ε and w/o S ({wos:.3}) ≥ w/S — {}",
        if ok { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
