//! Cross-DC demo: the real multi-worker EP runtime on throttled links.
//!
//! Spawns one worker thread per GPU (2 DCs × 4 GPUs by default); every
//! dispatch byte and every (SR-compressed) expert byte actually crosses a
//! bandwidth-throttled channel, and expert FFNs execute on the AOT Pallas
//! artifact via PJRT. Compares vanilla EP against HybridEP configurations
//! and reports measured iteration times (wall-clock, scaled).
//!
//!   cargo run --release --example cross_dc_demo [-- --iters 3 --scale 20]

use anyhow::Result;
use hybrid_ep::cluster::presets;
use hybrid_ep::coordinator::{run_cross_dc, CrossDcCfg};
use hybrid_ep::report::Table;
use hybrid_ep::runtime::Artifacts;
use hybrid_ep::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let arts = Artifacts::discover()?;
    let iters = args.usize_or("iters", 3)?;
    let scale = args.f64_or("scale", 20.0)?;
    // scaled-down bandwidths preserve the paper's 128:10 PCIe:Ethernet ratio
    let cluster = presets::dcs_x_gpus(2, 4, 10.0, 128.0);
    println!(
        "cluster: {} ({} workers), inter-DC 10 Gbps / intra 128 Gbps, time×{scale}",
        cluster.name,
        cluster.total_gpus()
    );

    let configs: Vec<(&str, Vec<usize>, Option<usize>)> = vec![
        ("Vanilla EP        (S_ED=[1,1])", vec![1, 1], None),
        ("Partition only    (S_ED=[2,4])", vec![2, 4], None),
        ("HybridEP CR=50×   (S_ED=[2,4])", vec![2, 4], Some(50)),
    ];

    let mut table = Table::new(
        "Cross-DC demo — measured iteration time (real bytes, real Pallas compute)",
        &["system", "iter time (sim)", "A2A bytes", "AG bytes", "speedup vs EP"],
    );
    let mut ep_time = None;
    for (name, partition, cr) in configs {
        let cfg = CrossDcCfg {
            cluster: cluster.clone(),
            time_scale: scale,
            partition,
            compression_ratio: cr,
            iterations: iters,
            seed: 7,
        };
        let stats = run_cross_dc(&arts, &cfg)?;
        // skip iteration 0 (compile warm-up), average the rest
        let avg = stats.iter().skip(1).map(|s| s.sim_secs).sum::<f64>()
            / (stats.len() - 1).max(1) as f64
            * scale;
        let a2a: usize = stats.iter().map(|s| s.a2a_bytes).sum::<usize>() / stats.len();
        let ag: usize = stats.iter().map(|s| s.ag_bytes).sum::<usize>() / stats.len();
        let speedup = ep_time.map(|t: f64| format!("{:.2}×", t / avg)).unwrap_or_default();
        if ep_time.is_none() {
            ep_time = Some(avg);
        }
        table.row(vec![
            name.to_string(),
            hybrid_ep::util::fmt_secs(avg),
            hybrid_ep::util::fmt_bytes(a2a as f64),
            hybrid_ep::util::fmt_bytes(ag as f64),
            speedup,
        ]);
    }
    table.print();
    Ok(())
}
