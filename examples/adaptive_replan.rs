//! Adaptive replanning demo: drifting gate skew on a heterogeneous
//! (straggler-DC) cluster, comparing never-migrate / always-replan /
//! adaptive policies, then the per-layer p_l profile for a skew-graded
//! layer trace.
//!
//!   cargo run --release --example adaptive_replan [-- --iters 16 --drift 3.5]

use anyhow::Result;
use hybrid_ep::cluster::presets;
use hybrid_ep::moe::MoEWorkload;
use hybrid_ep::plan::replanner::{self, Policy, ReplanCfg};
use hybrid_ep::report::Table;
use hybrid_ep::systems::hybrid_ep::{HybridEp, MigrationCfg};
use hybrid_ep::systems::SchedCtx;
use hybrid_ep::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let iters = args.usize_or("iters", 16)?;
    let drift = args.f64_or("drift", 3.5)?;
    let window = args.usize_or("window", 2)?;

    // 2 DCs × 4 GPUs; DC 0's uplink is a 2× straggler
    let cluster = presets::straggler_dc(2, 4, 10.0, 128.0, 0, 5.0);
    let w = MoEWorkload {
        tokens_per_gpu: 1024,
        hidden: 256,
        ffn: 2048,
        experts_per_gpu: 1,
        k: 1,
        moe_layers: 2,
        pre_blocks: 1,
        backward: false,
    };
    let g = cluster.total_gpus();
    let trace = replanner::drift_trace(g, g, w.tokens_per_gpu, w.k, 0.0, drift, 0.3, iters, 7)?;
    let cfg = ReplanCfg {
        migration: MigrationCfg { compression_ratio: 3.0, ..Default::default() },
        window,
    };

    println!(
        "cluster {} — skew ramp 0 → {drift} over {iters} iterations, window {window}",
        cluster.name
    );
    let mut table = Table::new(
        "Replanning policies over the drift trace",
        &["policy", "total", "switches", "final partition"],
    );
    for policy in [Policy::Never, Policy::Always, Policy::Adaptive] {
        let report = replanner::run_policy(&cluster, &w, &trace, &cfg, policy)?;
        table.row(vec![
            format!("{policy:?}"),
            hybrid_ep::util::fmt_secs(report.total_secs),
            report.switches.to_string(),
            format!("{:?}", report.records.last().map(|r| r.partition.clone()).unwrap_or_default()),
        ]);
    }
    table.print();

    // per-layer p_l profile over the trace's first few routings
    let layer_trace = &trace[..trace.len().min(4)];
    let mut ctx = SchedCtx::new(&cluster, &w, &trace[0]);
    ctx.layer_routing = Some(layer_trace);
    let hy = HybridEp::partition_only();
    let mut profile = Table::new("Per-layer partitions (p_l)", &["layer", "S_ED"]);
    for l in 0..layer_trace.len() {
        let part = hy.resolve_partition_for_layer(&ctx, l);
        profile.row(vec![l.to_string(), format!("{:?}", part.sizes())]);
    }
    profile.print();
    Ok(())
}
