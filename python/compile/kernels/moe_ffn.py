"""Layer-1 Pallas kernels: the MoE compute hot spot.

Two kernels, both grouped over experts:

* ``expert_ffn``      — ``gelu(x @ w1) @ w2`` for each expert (the EP hot GeMM
                        pair that HybridEP's stream model calls ``Lat_comp^Ep``).
* ``sr_decode_ffn``   — same FFN with the effective weights reconstructed as
                        ``shared + residual`` inside the kernel: the paper's
                        "SRDecode fused with expert computation" (§IV-B,
                        Fig. 9(b) / Fig. 15(b)).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper tiles the
expert GeMMs for CUDA threadblocks/shared memory; here the HBM↔VMEM schedule is
expressed with a ``(expert, token-tile)`` grid and ``BlockSpec``s. Per grid
step the kernel stages one token tile ``[BT, H]`` plus one expert's weights
``[H, M] + [M, H]`` in VMEM and issues two MXU-shaped ``dot``s. ``interpret=True``
is mandatory on this testbed: real TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO so the same
program runs (and is AOT-exported) on CPU.

VMEM budgeting (for the §Perf structural estimate): bytes staged per step are
``4*(BT*H + H*M + M*H + BT*M)``; ``choose_token_tile`` picks the largest BT
that (a) divides the capacity C and (b) keeps the working set under the 16 MiB
VMEM budget of a TPUv4-class core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPUv4-class VMEM budget (bytes) used for structural tuning of BT.
VMEM_BUDGET = 16 * 1024 * 1024
# MXU-friendly tile quanta.
LANE = 128
SUBLANE = 8


def choose_token_tile(c: int, h: int, m: int, dtype_bytes: int = 4) -> int:
    """Largest token tile BT dividing C whose working set fits VMEM.

    Working set per grid step: x tile [BT, H], w1 [H, M], w2 [M, H],
    intermediate [BT, M], output tile [BT, H].
    """
    weights = dtype_bytes * 2 * h * m
    best = 1
    for bt in range(1, c + 1):
        if c % bt:
            continue
        work = weights + dtype_bytes * (bt * h + bt * m + bt * h)
        if work <= VMEM_BUDGET:
            best = bt
    return best


def vmem_bytes(bt: int, h: int, m: int, dtype_bytes: int = 4) -> int:
    """VMEM working-set estimate for a (BT, H, M) tiling (used by §Perf)."""
    return dtype_bytes * (2 * h * m + bt * h + bt * m + bt * h)


def mxu_utilization(bt: int, h: int, m: int) -> float:
    """Fraction of MXU-aligned work in the two dots (structural estimate).

    The MXU consumes (8×128)·(128×128) tiles; a dot of shape [a,b]×[b,c]
    achieves roughly (a/⌈a⌉₈)·(b/⌈b⌉₁₂₈)·(c/⌈c⌉₁₂₈) utilization from shape
    alignment alone. We report the FLOP-weighted mean over the two GeMMs.
    """

    def ceil_to(x: int, q: int) -> int:
        return (x + q - 1) // q * q

    def util(a: int, b: int, c: int) -> float:
        return (a / ceil_to(a, SUBLANE)) * (b / ceil_to(b, LANE)) * (c / ceil_to(c, LANE))

    f1 = bt * h * m  # x @ w1
    f2 = bt * m * h  # h @ w2
    return (util(bt, h, m) * f1 + util(bt, m, h) * f2) / (f1 + f2)


def _ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One (expert, token-tile) step: two MXU dots + gelu, all in VMEM."""
    h = jnp.dot(x_ref[0], w1_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h)
    o_ref[0] = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32).astype(o_ref.dtype)


def expert_ffn_tiled(x: jax.Array, w1: jax.Array, w2: jax.Array, block_tokens: int | None = None):
    """Forward-only grouped expert FFN with explicit token tiling (bench/eval).

    Shapes: x [E,C,H], w1 [E,H,M], w2 [E,M,H]. Not differentiable; the
    training path uses :func:`expert_ffn` (custom VJP with Pallas backward).
    """
    e, c, h = x.shape
    _, _, m = w1.shape
    bt = block_tokens or choose_token_tile(c, h, m)
    assert c % bt == 0, f"capacity {c} not divisible by token tile {bt}"
    grid = (e, c // bt)
    return pl.pallas_call(
        _ffn_kernel,
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, m), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, m, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, h), lambda ei, ti: (ei, ti, 0)),
        interpret=True,
    )(x, w1, w2)


def _ffn_bwd_kernel(x_ref, w1_ref, w2_ref, dy_ref, dx_ref, dw1_ref, dw2_ref):
    """Backward kernel for one expert (grid=(E,)).

    Recomputes the forward activations in VMEM (rematerialization — nothing is
    saved from the forward pass but the inputs), then forms the three gradient
    GeMMs. The gelu derivative comes from ``jax.vjp`` so it stays exactly
    consistent with the forward kernel's gelu.
    """
    x = x_ref[0]
    w1 = w1_ref[0]
    w2 = w2_ref[0]
    dy = dy_ref[0]
    h1 = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    a, gelu_vjp = jax.vjp(jax.nn.gelu, h1)
    da = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dh1 = gelu_vjp(da)[0]
    dx_ref[0] = jnp.dot(dh1, w1.T, preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dw1_ref[0] = jnp.dot(x.T, dh1, preferred_element_type=jnp.float32).astype(dw1_ref.dtype)
    dw2_ref[0] = jnp.dot(a.T, dy, preferred_element_type=jnp.float32).astype(dw2_ref.dtype)


def _expert_ffn_bwd_pallas(x, w1, w2, dy):
    e, c, h = x.shape
    m = w1.shape[2]
    return pl.pallas_call(
        _ffn_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((e, c, h), x.dtype),
            jax.ShapeDtypeStruct((e, h, m), w1.dtype),
            jax.ShapeDtypeStruct((e, m, h), w2.dtype),
        ),
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, h), lambda ei: (ei, 0, 0)),
            pl.BlockSpec((1, h, m), lambda ei: (ei, 0, 0)),
            pl.BlockSpec((1, m, h), lambda ei: (ei, 0, 0)),
            pl.BlockSpec((1, c, h), lambda ei: (ei, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, c, h), lambda ei: (ei, 0, 0)),
            pl.BlockSpec((1, h, m), lambda ei: (ei, 0, 0)),
            pl.BlockSpec((1, m, h), lambda ei: (ei, 0, 0)),
        ),
        interpret=True,
    )(x, w1, w2, dy)


@jax.custom_vjp
def expert_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array):
    """Grouped expert FFN via Pallas, differentiable (custom VJP).

    Shapes: x [E,C,H], w1 [E,H,M], w2 [E,M,H] → [E,C,H]. Both the forward and
    the backward pass are Pallas kernels, so the whole training step lowers to
    kernel-shaped HLO.
    """
    return expert_ffn_tiled(x, w1, w2)


def _expert_ffn_fwd(x, w1, w2):
    return expert_ffn_tiled(x, w1, w2), (x, w1, w2)


def _expert_ffn_bwd(saved, dy):
    x, w1, w2 = saved
    return _expert_ffn_bwd_pallas(x, w1, w2, dy)


expert_ffn.defvjp(_expert_ffn_fwd, _expert_ffn_bwd)


def _sr_ffn_kernel(x_ref, sw1_ref, rw1_ref, sw2_ref, rw2_ref, o_ref):
    """Fused SRDecode + FFN: reconstruct w = shared + residual in-register.

    The residual add rides the same VMEM residency as the GeMM operands, so the
    decode costs no extra HBM round-trip — this is the fusion Fig. 15(b)
    measures as a ~45% SRDecode overhead reduction.
    """
    w1 = sw1_ref[...] + rw1_ref[0]
    w2 = sw2_ref[...] + rw2_ref[0]
    h = jnp.dot(x_ref[0], w1, preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h)
    o_ref[0] = jnp.dot(h, w2, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_tokens",))
def sr_decode_ffn(
    x: jax.Array,
    shared_w1: jax.Array,
    res_w1: jax.Array,
    shared_w2: jax.Array,
    res_w2: jax.Array,
    block_tokens: int | None = None,
):
    """SRDecode-fused grouped expert FFN.

    Shapes: x [E,C,H], shared_w1 [H,M], res_w1 [E,H,M], shared_w2 [M,H],
    res_w2 [E,M,H]. Residuals are dense here; sparse→dense densification of the
    value+index wire format happens on the Rust side (or in jnp for tests).
    """
    e, c, h = x.shape
    m = shared_w1.shape[1]
    bt = block_tokens or choose_token_tile(c, h, m)
    assert c % bt == 0, f"capacity {c} not divisible by token tile {bt}"
    grid = (e, c // bt)
    return pl.pallas_call(
        _sr_ffn_kernel,
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((h, m), lambda ei, ti: (0, 0)),
            pl.BlockSpec((1, h, m), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((m, h), lambda ei, ti: (0, 0)),
            pl.BlockSpec((1, m, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, h), lambda ei, ti: (ei, ti, 0)),
        interpret=True,
    )(x, shared_w1, res_w1, shared_w2, res_w2)
