"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only. ``python/tests`` asserts the Pallas kernels
(run under ``interpret=True``) match these oracles with ``assert_allclose``
across hypothesis-driven shape/dtype sweeps.

Also hosts the reference SR (shared + residual) expert-compression codec from
HybridEP §IV-B, used both to validate the fused-decode Pallas kernel and to
produce golden vectors for the Rust codec (``rust/src/migration/sr_codec.rs``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    """Grouped expert FFN: ``gelu(x @ w1) @ w2`` per expert.

    Args:
      x:  [E, C, H] tokens dispatched to each expert (capacity C).
      w1: [E, H, M] first expert weight.
      w2: [E, M, H] second expert weight.

    Returns:
      [E, C, H] expert outputs.
    """
    h = jnp.einsum("ech,ehm->ecm", x, w1)
    h = jax.nn.gelu(h)
    return jnp.einsum("ecm,emh->ech", h, w2)


def sr_decode_ffn_ref(
    x: jax.Array,
    shared_w1: jax.Array,
    res_w1: jax.Array,
    shared_w2: jax.Array,
    res_w2: jax.Array,
) -> jax.Array:
    """SRDecode fused with the expert FFN (HybridEP §IV-B decode phase).

    The effective expert weight is ``shared + residual`` (residual already
    densified from the value+index wire format). Fusing the add with the FFN
    GeMMs is what the paper reports as the 45% SRDecode overhead reduction.

    Args:
      x:         [E, C, H]
      shared_w1: [H, M]   shared expert, first matrix.
      res_w1:    [E, H, M] dense residuals per expert.
      shared_w2: [M, H]
      res_w2:    [E, M, H]
    """
    w1 = shared_w1[None, :, :] + res_w1
    w2 = shared_w2[None, :, :] + res_w2
    return expert_ffn_ref(x, w1, w2)


# ---------------------------------------------------------------------------
# SR codec reference (mirrors rust/src/migration/sr_codec.rs)
# ---------------------------------------------------------------------------


def sr_encode_ref(w: jax.Array, shared: jax.Array, k: int):
    """Encode expert ``w`` against ``shared``: Top-k |residual| in value+index form.

    Returns ``(values[k], indices[k])`` over the flattened residual, with
    indices in ascending order (the canonical wire order shared with the Rust
    codec so golden vectors compare exactly).
    """
    res = (w - shared).reshape(-1)
    k = int(k)
    _, idx = jax.lax.top_k(jnp.abs(res), k)
    idx = jnp.sort(idx)  # deterministic canonical order: ascending index
    vals = res[idx]
    return vals, idx.astype(jnp.int32)


def sr_decode_dense_ref(shared: jax.Array, vals: jax.Array, idx: jax.Array):
    """Decode value+index residual onto the shared expert (dense restore)."""
    flat = jnp.zeros(shared.size, shared.dtype).at[idx].set(vals)
    return shared + flat.reshape(shared.shape)


def sr_roundtrip_ref(w: jax.Array, shared: jax.Array, k: int) -> jax.Array:
    """decode(encode(w)) — the lossy migration a remote GPU observes."""
    vals, idx = sr_encode_ref(w, shared, k)
    return sr_decode_dense_ref(shared, vals, idx)
