"""AOT compile path: lower every Layer-2/Layer-1 entry point to HLO text.

Run once by ``make artifacts`` (never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Produces in ``artifacts/``:

* ``train_step_<profile>.hlo.txt`` / ``eval_<profile>.hlo.txt`` — full training
  step (fwd + bwd + Adam, Pallas expert kernels inside) and eval loss, for the
  ``test``/``small``/``large`` model profiles.
* ``params_<profile>.bin`` — initial parameters, flat f32 concatenation in
  ``flatten_spec`` order (little-endian), so Rust reproduces python init
  exactly.
* ``expert_ffn_demo.hlo.txt`` / ``sr_decode_ffn_demo.hlo.txt`` /
  ``pre_expert_demo.hlo.txt`` — standalone stages for the Rust multi-worker
  cross-DC runtime and the Fig. 11/12/15 benches.
* ``gemm_<L>x<H>x<M>.hlo.txt`` — bare GeMMs for Fig. 11 compute verification.
* ``manifest.json`` — input names/shapes/dtypes per artifact, model configs,
  expert-weight slot indices (for SR migration), parameter counts.
* ``golden_sr.json`` — reference SR-codec vectors for the Rust codec tests.

Interchange format is HLO **text**: jax ≥ 0.5 serialized HloModuleProto uses
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import moe_ffn, ref

# ---------------------------------------------------------------------------
# Model profiles (paper Table II analogues, scaled for this testbed)
# ---------------------------------------------------------------------------

PROFILES: dict[str, model.MoEConfig] = {
    # tiny: python tests + rust integration tests (sub-second everything);
    # high lr so learning is visible within a ~30-step test horizon
    "test": model.MoEConfig(
        vocab=64, seq=16, batch=2, h=32, m=64, e=4, k=2, n_layers=2, n_heads=2,
        lr=1e-2,
    ),
    # default end-to-end profile (~20M params), a few hundred steps in minutes
    "small": model.MoEConfig(
        vocab=512, seq=64, batch=8, h=256, m=768, e=24, k=2, n_layers=4,
        n_heads=4, moe_every=2,
    ),
    # ~100M-param profile for the headline train_e2e run
    "large": model.MoEConfig(
        vocab=1024, seq=64, batch=8, h=512, m=768, e=40, k=1, n_layers=6,
        n_heads=8, moe_every=2,
    ),
}

# Demo stage dimensions for the multi-worker cross-DC runtime: one MoE block
# worth of work per worker (B=4 local batch).
DEMO = model.MoEConfig(
    vocab=256, seq=32, batch=4, h=128, m=256, e=8, k=1, n_layers=1, n_heads=4
)

GEMM_SIZES = [(128, 128, 128), (256, 256, 256), (512, 512, 512), (1024, 1024, 1024)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by text parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_artifact(out_dir: str, name: str, fn, example_args, input_names=None) -> dict:
    """Lower ``fn`` at ``example_args``, write HLO text, return manifest entry."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_tree = jax.eval_shape(fn, *example_args)
    outputs = [_spec_of(o) for o in jax.tree_util.tree_leaves(out_tree)]
    names = input_names or [f"arg{i}" for i in range(len(example_args))]
    inputs = [{"name": n, **_spec_of(a)} for n, a in zip(names, example_args)]
    print(f"  {fname}: {len(text) / 1e6:.2f} MB HLO, {len(inputs)} inputs, {len(outputs)} outputs")
    return {"file": fname, "inputs": inputs, "outputs": outputs}


def build_profile(out_dir: str, pname: str, cfg: model.MoEConfig) -> dict:
    """Lower train_step + eval for one profile; dump init params."""
    print(f"profile {pname}: {dataclasses.asdict(cfg)}")
    params = model.init_params(cfg, jax.random.PRNGKey(42))
    leaves = jax.tree_util.tree_leaves(params)
    spec = model.flatten_spec(cfg)
    assert len(leaves) == len(spec)

    # init params binary (flat f32 LE concat in flatten order)
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves])
    flat.tofile(os.path.join(out_dir, f"params_{pname}.bin"))

    batch = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    t0 = jax.ShapeDtypeStruct((), jnp.float32)
    state_shapes = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]

    flat_step, n = model.make_flat_train_step(cfg)
    step_names = (
        ["batch", "t"]
        + [f"params/{s['name']}" for s in spec]
        + [f"m/{s['name']}" for s in spec]
        + [f"v/{s['name']}" for s in spec]
    )
    train_entry = lower_artifact(
        out_dir,
        f"train_step_{pname}",
        flat_step,
        [batch, t0, *state_shapes, *state_shapes, *state_shapes],
        step_names,
    )

    flat_eval, _ = model.make_flat_eval(cfg)
    eval_entry = lower_artifact(
        out_dir,
        f"eval_{pname}",
        flat_eval,
        [batch, *state_shapes],
        ["batch"] + [f"params/{s['name']}" for s in spec],
    )

    return {
        "config": dataclasses.asdict(cfg),
        "param_count": int(flat.size),
        "n_leaves": n,
        "capacity": cfg.capacity,
        "expert_param_bytes": 4 * cfg.expert_params,
        "params_file": f"params_{pname}.bin",
        "param_spec": spec,
        "expert_slots": [i for i, s in enumerate(spec) if s["expert_weight"]],
        "train_step": train_entry,
        "eval": eval_entry,
    }


def build_demo(out_dir: str) -> dict:
    """Standalone stage artifacts for the multi-worker runtime + benches."""
    cfg = DEMO
    e, c, h, m = cfg.e, cfg.capacity, cfg.h, cfg.m
    x = jax.ShapeDtypeStruct((e, c, h), jnp.float32)
    w1 = jax.ShapeDtypeStruct((e, h, m), jnp.float32)
    w2 = jax.ShapeDtypeStruct((e, m, h), jnp.float32)
    sw1 = jax.ShapeDtypeStruct((h, m), jnp.float32)
    sw2 = jax.ShapeDtypeStruct((m, h), jnp.float32)

    entries = {
        "expert_ffn": lower_artifact(
            out_dir, "expert_ffn_demo",
            lambda a, b, c_: (moe_ffn.expert_ffn_tiled(a, b, c_),),
            [x, w1, w2], ["x", "w1", "w2"],
        ),
        "sr_decode_ffn": lower_artifact(
            out_dir, "sr_decode_ffn_demo",
            lambda a, s1, r1, s2, r2: (moe_ffn.sr_decode_ffn(a, s1, r1, s2, r2),),
            [x, sw1, w1, sw2, w2], ["x", "shared_w1", "res_w1", "shared_w2", "res_w2"],
        ),
    }

    pre = model.make_pre_expert(cfg)
    xx = jax.ShapeDtypeStruct((cfg.batch, cfg.seq, h), jnp.float32)
    ww = jax.ShapeDtypeStruct((h, h), jnp.float32)
    gg = jax.ShapeDtypeStruct((h, cfg.e), jnp.float32)
    entries["pre_expert"] = lower_artifact(
        out_dir, "pre_expert_demo", pre,
        [xx, ww, ww, ww, ww, gg], ["x", "wq", "wk", "wv", "wo", "gate"],
    )
    return {"config": dataclasses.asdict(cfg), "capacity": cfg.capacity, "entries": entries}


def build_gemms(out_dir: str) -> dict:
    entries = {}
    for (l, h, m) in GEMM_SIZES:
        a = jax.ShapeDtypeStruct((l, h), jnp.float32)
        b = jax.ShapeDtypeStruct((h, m), jnp.float32)
        entries[f"{l}x{h}x{m}"] = lower_artifact(
            out_dir, f"gemm_{l}x{h}x{m}", lambda x, y: (x @ y,), [a, b], ["x", "y"]
        )
    return entries


def build_golden_sr(out_dir: str) -> None:
    """Golden vectors so the Rust SR codec can be cross-checked bit-for-bit."""
    rng = np.random.default_rng(7)
    cases = []
    for n, k in [(16, 4), (64, 8), (256, 32), (256, 256)]:
        w = rng.standard_normal(n).astype(np.float32)
        shared = rng.standard_normal(n).astype(np.float32) * 0.5
        vals, idx = ref.sr_encode_ref(jnp.array(w), jnp.array(shared), k)
        dec = ref.sr_decode_dense_ref(jnp.array(shared), vals, idx)
        cases.append(
            {
                "n": n,
                "k": k,
                "w": w.tolist(),
                "shared": shared.tolist(),
                "values": np.asarray(vals).tolist(),
                "indices": np.asarray(idx).tolist(),
                "decoded": np.asarray(dec).tolist(),
            }
        )
    with open(os.path.join(out_dir, "golden_sr.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  golden_sr.json: {len(cases)} cases")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--profiles", default="test,small,large",
        help="comma-separated subset of profiles to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"profiles": {}, "demo": None, "gemm": {}}
    for pname in args.profiles.split(","):
        manifest["profiles"][pname] = build_profile(args.out, pname, PROFILES[pname])
    manifest["demo"] = build_demo(args.out)
    manifest["gemm"] = build_gemms(args.out)
    build_golden_sr(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json written to {args.out}")


if __name__ == "__main__":
    main()
