"""Layer-2: the MoE transformer in JAX (build-time only).

Implements the model family of HybridEP's evaluation (Table II): a GPT-style
decoder where every ``moe_every``-th block replaces the dense FFN with an MoE
layer (gate network + Top-K routing + capacity dispatch + grouped expert FFN).
The expert FFN is the Layer-1 Pallas kernel (``kernels.moe_ffn.expert_ffn``),
so the AOT lowering of any function here carries the kernel in the same HLO.

Everything a training iteration needs — forward, loss, backward, Adam — is a
single pure function ``train_step`` so the Rust coordinator can drive training
by repeatedly executing one PJRT executable with Python fully out of the loop.

Parameters travel as a flat list of arrays; ``flatten_spec`` publishes the
canonical (name, shape, dtype) order that ``aot.py`` writes into
``artifacts/manifest.json`` and the Rust runtime replays.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import moe_ffn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Model/training configuration (paper Table II/III vocabulary).

    E = num experts, K = activated experts, H/M = the two expert dimensions,
    B = batch, L = sequence length.
    """

    vocab: int = 256
    seq: int = 64
    batch: int = 8
    h: int = 128
    m: int = 256
    e: int = 8
    k: int = 2
    n_layers: int = 2
    n_heads: int = 4
    moe_every: int = 1  # every n-th block is MoE (1 = all blocks MoE)
    capacity_factor: float = 1.25
    lr: float = 1e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8

    @property
    def tokens(self) -> int:
        return self.batch * self.seq

    @property
    def capacity(self) -> int:
        """Per-expert token capacity, rounded up to a multiple of 8."""
        c = math.ceil(self.tokens * self.k * self.capacity_factor / self.e)
        return max(8, (c + 7) // 8 * 8)

    @property
    def expert_params(self) -> int:
        """P_E of the stream model: parameters of one expert."""
        return 2 * self.h * self.m

    def is_moe_block(self, i: int) -> bool:
        return (i + 1) % self.moe_every == 0

    def param_count(self, params=None) -> int:
        p = params if params is not None else init_params(self, jax.random.PRNGKey(0))
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(p))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: MoEConfig, key: jax.Array) -> dict[str, Any]:
    """Initialize all parameters as a (sorted-key) nested dict pytree."""
    h, m = cfg.h, cfg.m
    keys = iter(jax.random.split(key, 8 + 16 * cfg.n_layers))

    def dense(k, shape, scale=None):
        scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    params: dict[str, Any] = {
        "embed": dense(next(keys), (cfg.vocab, h), scale=0.02),
        "pos": dense(next(keys), (cfg.seq, h), scale=0.02),
        "ln_f": {"g": jnp.ones((h,)), "b": jnp.zeros((h,))},
    }
    blocks = []
    for i in range(cfg.n_layers):
        blk: dict[str, Any] = {
            "ln1": {"g": jnp.ones((h,)), "b": jnp.zeros((h,))},
            "ln2": {"g": jnp.ones((h,)), "b": jnp.zeros((h,))},
            "attn": {
                "wq": dense(next(keys), (h, h)),
                "wk": dense(next(keys), (h, h)),
                "wv": dense(next(keys), (h, h)),
                "wo": dense(next(keys), (h, h)),
            },
        }
        if cfg.is_moe_block(i):
            blk["moe"] = {
                "gate": dense(next(keys), (h, cfg.e), scale=0.02),
                "w1": dense(next(keys), (cfg.e, h, m)),
                "w2": dense(next(keys), (cfg.e, m, h)),
            }
        else:
            blk["ffn"] = {
                "w1": dense(next(keys), (h, m)),
                "w2": dense(next(keys), (m, h)),
            }
        blocks.append(blk)
    params["blocks"] = blocks
    return params


def flatten_spec(cfg: MoEConfig) -> list[dict[str, Any]]:
    """Canonical flat parameter order: [{name, shape, dtype, expert_weight}]."""
    params = init_params(cfg, jax.random.PRNGKey(0))
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    spec = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                # expert FFN weights are the ones SR-migration compresses
                "expert_weight": ("moe/w1" in name or "moe/w2" in name),
            }
        )
    return spec


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: MoEConfig, p, x):
    """Causal multi-head attention. x: [B, S, H]."""
    b, s, h = x.shape
    nh, hd = cfg.n_heads, h // cfg.n_heads

    def split(w):
        return (x @ w).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    att = jnp.einsum("bnqd,bnkd->bnqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bnqk,bnkd->bnqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h)
    return out @ p["wo"]


def _topk_iterative(x: jax.Array, k: int):
    """Top-k along the last axis via k argmax+mask rounds.

    ``jax.lax.top_k`` lowers to the modern HLO ``topk`` op, which the
    xla_extension 0.5.1 text parser used by the Rust runtime rejects;
    iterative argmax lowers to plain reduces and round-trips cleanly.
    K is small (1–4) in every paper configuration, so the cost is negligible.
    """
    vals, idxs = [], []
    cur = x
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)  # [T]
        v = jnp.take_along_axis(cur, i[:, None], axis=-1)[:, 0]
        idxs.append(i)
        vals.append(v)
        mask = jax.nn.one_hot(i, x.shape[-1], dtype=jnp.bool_)
        cur = jnp.where(mask, -jnp.inf, cur)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_dispatch(cfg: MoEConfig, gate_logits: jax.Array):
    """Top-K capacity-constrained routing (Switch/GShard style).

    gate_logits: [T, E]. Returns (dispatch [T,E,C] f32 0/1, combine [T,E,C]).
    Tokens overflowing an expert's capacity are dropped (standard EP
    semantics; HybridEP's modeling assumes even activation, §III).
    """
    t, e = gate_logits.shape
    c = cfg.capacity
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_vals, idx = _topk_iterative(probs, cfg.k)  # [T, K]
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # [T, K, E]
    # position of each (token, k) within its expert queue, counting k-major
    flat = onehot.transpose(1, 0, 2).reshape(cfg.k * t, e)  # [K*T, E]
    pos_flat = jnp.cumsum(flat, axis=0) - flat  # [K*T, E]
    pos = pos_flat.reshape(cfg.k, t, e).transpose(1, 0, 2)  # [T, K, E]
    pos = jnp.sum(pos * onehot, axis=-1)  # [T, K]
    keep = pos < c
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32) * keep[..., None]  # [T,K,C]
    dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
    combine = jnp.einsum("tk,tke,tkc->tec", gate_vals, onehot, pos_oh)
    return dispatch, combine


def _moe_layer(cfg: MoEConfig, p, x):
    """MoE block body: gate → dispatch → Pallas expert FFN → combine.

    x: [B, S, H] → [B, S, H].
    """
    b, s, h = x.shape
    xt = x.reshape(b * s, h)
    dispatch, combine = moe_dispatch(cfg, xt @ p["gate"])
    xin = jnp.einsum("tec,th->ech", dispatch, xt)  # [E, C, H]
    out = moe_ffn.expert_ffn(xin, p["w1"], p["w2"])  # Pallas L1 kernel
    y = jnp.einsum("tec,ech->th", combine, out)
    return y.reshape(b, s, h)


def _dense_ffn(p, x):
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


def forward(cfg: MoEConfig, params, tokens: jax.Array) -> jax.Array:
    """tokens [B, S] int32 → logits [B, S, vocab]."""
    x = params["embed"][tokens] + params["pos"][None, : tokens.shape[1]]
    for blk in params["blocks"]:
        x = x + _attention(cfg, blk["attn"], _layer_norm(x, blk["ln1"]["g"], blk["ln1"]["b"]))
        xn = _layer_norm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        if "moe" in blk:
            x = x + _moe_layer(cfg, blk["moe"], xn)
        else:
            x = x + _dense_ffn(blk["ffn"], xn)
    x = _layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    return x @ params["embed"].T  # tied LM head


def loss_fn(cfg: MoEConfig, params, batch: jax.Array) -> jax.Array:
    """Next-token cross-entropy. batch: [B, S+1] int32."""
    inp, tgt = batch[:, :-1], batch[:, 1:]
    logits = forward(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ---------------------------------------------------------------------------
# Training step (fwd + bwd + Adam in one jittable function)
# ---------------------------------------------------------------------------


def make_train_step(cfg: MoEConfig):
    """Returns ``step(params, m, v, t, batch) -> (params', m', v', t+1, loss)``.

    All states are pytrees with the ``flatten_spec`` structure; ``t`` is a
    float32 scalar step counter (for Adam bias correction).
    """

    def train_step(params, m, v, t, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        t1 = t + 1.0
        b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr

        def upd(p, g, mi, vi):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t1)
            vhat = vi / (1 - b2**t1)
            return p - lr * mhat / (jnp.sqrt(vhat) + eps), mi, vi

        out = jax.tree_util.tree_map(upd, params, grads, m, v)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_m, new_v, t1, loss

    return train_step


def make_flat_train_step(cfg: MoEConfig):
    """Flat-list variant for AOT: inputs/outputs are positional arrays.

    Signature: ``(batch_i32[B,S+1], t_f32[], *params, *m, *v) ->
    (loss_f32[], t+1, *params', *m', *v')``.
    """
    treedef = jax.tree_util.tree_structure(init_params(cfg, jax.random.PRNGKey(0)))
    n = treedef.num_leaves
    step = make_train_step(cfg)

    def flat_step(batch, t, *flat):
        assert len(flat) == 3 * n, f"expected {3 * n} state arrays, got {len(flat)}"
        params = jax.tree_util.tree_unflatten(treedef, flat[:n])
        m = jax.tree_util.tree_unflatten(treedef, flat[n : 2 * n])
        v = jax.tree_util.tree_unflatten(treedef, flat[2 * n :])
        params, m, v, t1, loss = step(params, m, v, t, batch)
        return (
            loss,
            t1,
            *jax.tree_util.tree_leaves(params),
            *jax.tree_util.tree_leaves(m),
            *jax.tree_util.tree_leaves(v),
        )

    return flat_step, n


def make_flat_eval(cfg: MoEConfig):
    """Flat eval loss: ``(batch, *params) -> (loss,)``."""
    treedef = jax.tree_util.tree_structure(init_params(cfg, jax.random.PRNGKey(0)))
    n = treedef.num_leaves

    def flat_eval(batch, *flat):
        params = jax.tree_util.tree_unflatten(treedef, flat[:n])
        return (loss_fn(cfg, params, batch),)

    return flat_eval, n


# ---------------------------------------------------------------------------
# Standalone pieces for the Rust multi-worker runtime (cross_dc_demo)
# ---------------------------------------------------------------------------


def make_pre_expert(cfg: MoEConfig):
    """Pre-expert stage of one block: LN + attention + LN + gate logits.

    ``(x[B,S,H], wq, wk, wv, wo, gate[H,E]) -> (h[B,S,H], gate_logits[T,E])``
    This is ``Lat_comp^PE`` of the stream model, runnable per-worker.
    """

    def pre_expert(x, wq, wk, wv, wo, gate):
        p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
        g = jnp.ones((cfg.h,))
        b = jnp.zeros((cfg.h,))
        h = x + _attention(cfg, p, _layer_norm(x, g, b))
        hn = _layer_norm(h, g, b)
        logits = hn.reshape(-1, cfg.h) @ gate
        return h, logits

    return pre_expert
