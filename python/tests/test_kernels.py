"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes/tilings; every case asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moe_ffn, ref

ATOL = 2e-4
RTOL = 2e-4


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 5),
    c_blocks=st.integers(1, 4),
    bt=st.sampled_from([1, 2, 4, 8]),
    h=st.sampled_from([4, 8, 16, 32]),
    m=st.sampled_from([4, 12, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(e, c_blocks, bt, h, m, seed):
    rng = np.random.default_rng(seed)
    c = c_blocks * bt
    x, w1, w2 = rand(rng, e, c, h), rand(rng, e, h, m), rand(rng, e, m, h)
    got = moe_ffn.expert_ffn_tiled(x, w1, w2, block_tokens=bt)
    want = ref.expert_ffn_ref(x, w1, w2)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(
    e=st.integers(1, 4),
    c=st.sampled_from([8, 16]),
    h=st.sampled_from([8, 16]),
    m=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sr_decode_ffn_matches_ref(e, c, h, m, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, e, c, h)
    sw1, rw1 = rand(rng, h, m), rand(rng, e, h, m)
    sw2, rw2 = rand(rng, m, h), rand(rng, e, m, h)
    got = moe_ffn.sr_decode_ffn(x, sw1, rw1, sw2, rw2)
    want = ref.sr_decode_ffn_ref(x, sw1, rw1, sw2, rw2)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_sr_decode_ffn_equals_plain_ffn_on_reconstructed_weights():
    """decode-then-ffn == fused kernel (the fusion is exact, not approximate)."""
    rng = np.random.default_rng(0)
    e, c, h, m = 3, 8, 16, 24
    x = rand(rng, e, c, h)
    sw1, rw1 = rand(rng, h, m), rand(rng, e, h, m)
    sw2, rw2 = rand(rng, m, h), rand(rng, e, m, h)
    fused = moe_ffn.sr_decode_ffn(x, sw1, rw1, sw2, rw2)
    plain = moe_ffn.expert_ffn_tiled(x, sw1[None] + rw1, sw2[None] + rw2)
    np.testing.assert_allclose(fused, plain, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(
    e=st.integers(1, 3),
    c=st.sampled_from([4, 8]),
    h=st.sampled_from([4, 8]),
    m=st.sampled_from([4, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_grads_match_ref(e, c, h, m, seed):
    rng = np.random.default_rng(seed)
    x, w1, w2 = rand(rng, e, c, h), rand(rng, e, h, m), rand(rng, e, m, h)

    def f(fn):
        return lambda a, b, cc: jnp.sum(jnp.sin(fn(a, b, cc)))

    g = jax.grad(f(moe_ffn.expert_ffn), argnums=(0, 1, 2))(x, w1, w2)
    gr = jax.grad(f(ref.expert_ffn_ref), argnums=(0, 1, 2))(x, w1, w2)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-3)


def test_choose_token_tile_divides_and_fits():
    for c in [8, 16, 24, 64]:
        for h, m in [(64, 128), (512, 1024), (1024, 4096)]:
            bt = moe_ffn.choose_token_tile(c, h, m)
            assert c % bt == 0
            assert moe_ffn.vmem_bytes(bt, h, m) <= moe_ffn.VMEM_BUDGET or bt == 1


def test_mxu_utilization_bounds():
    assert moe_ffn.mxu_utilization(128, 128, 128) == pytest.approx(1.0)
    u = moe_ffn.mxu_utilization(7, 100, 100)
    assert 0.0 < u < 1.0


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 64, 256]),
    frac=st.sampled_from([0.1, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sr_roundtrip_error_monotone(n, frac, seed):
    """Roundtrip error is bounded and k=n is exact."""
    rng = np.random.default_rng(seed)
    w = jnp.array(rng.standard_normal(n).astype(np.float32))
    shared = jnp.array(rng.standard_normal(n).astype(np.float32))
    k = max(1, int(n * frac))
    rt = ref.sr_roundtrip_ref(w, shared, k)
    err = float(jnp.max(jnp.abs(rt - w)))
    res_max = float(jnp.max(jnp.abs(w - shared)))
    assert err <= res_max + 1e-6
    full = ref.sr_roundtrip_ref(w, shared, n)
    np.testing.assert_allclose(full, w, atol=1e-6)


def test_sr_encode_picks_largest_residuals():
    w = jnp.array([0.0, 10.0, 0.1, -7.0], jnp.float32)
    shared = jnp.zeros(4, jnp.float32)
    vals, idx = ref.sr_encode_ref(w, shared, 2)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    np.testing.assert_allclose(np.sort(np.asarray(vals)), [-7.0, 10.0])
