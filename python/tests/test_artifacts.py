"""Manifest/artifact consistency (requires `make artifacts` to have run)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_files_exist():
    m = manifest()
    for prof in m["profiles"].values():
        for entry in (prof["train_step"], prof["eval"]):
            assert os.path.exists(os.path.join(ART, entry["file"]))
        assert os.path.exists(os.path.join(ART, prof["params_file"]))
    for entry in m["demo"]["entries"].values():
        assert os.path.exists(os.path.join(ART, entry["file"]))
    for entry in m["gemm"].values():
        assert os.path.exists(os.path.join(ART, entry["file"]))


def test_params_bin_matches_spec_size():
    m = manifest()
    for prof in m["profiles"].values():
        total = sum(int(np.prod(s["shape"])) for s in prof["param_spec"])
        assert total == prof["param_count"]
        data = np.fromfile(os.path.join(ART, prof["params_file"]), np.float32)
        assert data.size == total
        assert np.isfinite(data).all()


def test_train_step_io_arity():
    m = manifest()
    for prof in m["profiles"].values():
        n = prof["n_leaves"]
        ts = prof["train_step"]
        assert len(ts["inputs"]) == 2 + 3 * n
        assert len(ts["outputs"]) == 2 + 3 * n
        assert ts["inputs"][0]["name"] == "batch"
        assert ts["inputs"][1]["name"] == "t"
        # output arity mirrors input state: loss, t, then state
        for i, s in zip(ts["inputs"][2:], ts["outputs"][2:]):
            assert i["shape"] == s["shape"], i


def test_expert_slots_shapes():
    m = manifest()
    for prof in m["profiles"].values():
        e = prof["config"]["e"]
        for i in prof["expert_slots"]:
            assert prof["param_spec"][i]["shape"][0] == e


def test_golden_sr_cases_well_formed():
    with open(os.path.join(ART, "golden_sr.json")) as f:
        g = json.load(f)
    for case in g["cases"]:
        assert len(case["w"]) == case["n"]
        assert len(case["values"]) == case["k"]
        assert len(case["indices"]) == case["k"]
        assert sorted(case["indices"]) == case["indices"]
        dec = np.array(case["decoded"])
        w = np.array(case["w"])
        sh = np.array(case["shared"])
        if case["k"] == case["n"]:
            np.testing.assert_allclose(dec, w, atol=1e-6)
        # decoded equals w at encoded indices, shared elsewhere
        idx = set(case["indices"])
        for j in range(case["n"]):
            target = w[j] if j in idx else sh[j]
            assert abs(dec[j] - target) < 1e-5
