"""L2 model tests: routing invariants, shapes, training signal, flat interface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

CFG = model.MoEConfig(vocab=64, seq=16, batch=2, h=32, m=64, e=4, k=2, n_layers=2, n_heads=2)


def test_param_shapes_match_spec():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(params)
    spec = model.flatten_spec(CFG)
    assert len(leaves) == len(spec)
    for leaf, s in zip(leaves, spec):
        assert list(leaf.shape) == s["shape"], s["name"]
        assert str(leaf.dtype) == s["dtype"]


def test_expert_slots_are_moe_weights():
    spec = model.flatten_spec(CFG)
    slots = [i for i, s in enumerate(spec) if s["expert_weight"]]
    assert len(slots) == 2 * sum(CFG.is_moe_block(i) for i in range(CFG.n_layers))
    for i in slots:
        assert spec[i]["shape"][0] == CFG.e


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 4))
def test_dispatch_invariants(seed, k):
    """Each token occupies ≤ K capacity slots; each (expert, slot) ≤ 1 token;
    combine weights are ≤ the gate probability mass."""
    cfg = model.MoEConfig(vocab=64, seq=8, batch=2, h=16, m=32, e=4, k=k, n_heads=2)
    rng = np.random.default_rng(seed)
    logits = jnp.array(rng.standard_normal((cfg.tokens, cfg.e)).astype(np.float32))
    dispatch, combine = model.moe_dispatch(cfg, logits)
    t, e, c = dispatch.shape
    assert (e, c) == (cfg.e, cfg.capacity)
    d = np.asarray(dispatch)
    assert set(np.unique(d)).issubset({0.0, 1.0})
    # each (expert, slot) holds at most one token
    assert d.sum(axis=0).max() <= 1.0 + 1e-6
    # each token dispatched to at most K slots
    assert d.sum(axis=(1, 2)).max() <= cfg.k + 1e-6
    # combine nonzero only where dispatched, and bounded by 1
    cm = np.asarray(combine)
    assert np.all(cm[d == 0.0] == 0.0)
    assert cm.max() <= 1.0 + 1e-6


def test_dispatch_respects_capacity_under_skew():
    """All tokens routed to one expert: dispatched count == capacity exactly."""
    cfg = model.MoEConfig(vocab=64, seq=8, batch=4, h=16, m=32, e=4, k=1, n_heads=2)
    logits = jnp.zeros((cfg.tokens, cfg.e)).at[:, 2].set(100.0)
    dispatch, _ = model.moe_dispatch(cfg, logits)
    per_expert = np.asarray(dispatch).sum(axis=(0, 2))
    assert per_expert[2] == min(cfg.tokens, cfg.capacity)
    assert per_expert[[0, 1, 3]].sum() == 0


def test_forward_shapes_and_finite():
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    toks = jnp.zeros((CFG.batch, CFG.seq), jnp.int32)
    logits = model.forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases_on_fixed_batch():
    params = model.init_params(CFG, jax.random.PRNGKey(1))
    step = jax.jit(model.make_train_step(CFG))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    m, v = zeros, zeros
    rng = np.random.default_rng(0)
    batch = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)
    t = jnp.float32(0.0)
    params, m, v, t, loss0 = step(params, m, v, t, batch)
    for _ in range(15):
        params, m, v, t, loss = step(params, m, v, t, batch)
    assert float(loss) < float(loss0)


def test_flat_train_step_matches_pytree_step():
    params = model.init_params(CFG, jax.random.PRNGKey(2))
    leaves = jax.tree_util.tree_leaves(params)
    zeros = [jnp.zeros_like(l) for l in leaves]
    rng = np.random.default_rng(3)
    batch = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)

    flat_step, n = model.make_flat_train_step(CFG)
    out = jax.jit(flat_step)(batch, jnp.float32(0.0), *leaves, *zeros, *zeros)
    loss_flat = float(out[0])

    step = jax.jit(model.make_train_step(CFG))
    zt = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _, _, loss_tree = step(params, zt, zt, jnp.float32(0.0), batch)
    assert loss_flat == pytest.approx(float(loss_tree), rel=1e-6)
    # first updated param identical through both interfaces
    np.testing.assert_allclose(out[2], jax.tree_util.tree_leaves(p2)[0], atol=1e-6)


def test_eval_matches_loss_fn():
    params = model.init_params(CFG, jax.random.PRNGKey(4))
    leaves = jax.tree_util.tree_leaves(params)
    rng = np.random.default_rng(5)
    batch = jnp.array(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)
    flat_eval, _ = model.make_flat_eval(CFG)
    (loss,) = jax.jit(flat_eval)(batch, *leaves)
    want = model.loss_fn(CFG, params, batch)
    assert float(loss) == pytest.approx(float(want), rel=1e-6)


def test_pre_expert_shapes():
    cfg = model.MoEConfig(vocab=64, seq=8, batch=2, h=16, m=32, e=4, k=1, n_heads=2)
    pre = model.make_pre_expert(cfg)
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((cfg.batch, cfg.seq, cfg.h)).astype(np.float32))
    w = jnp.array(rng.standard_normal((cfg.h, cfg.h)).astype(np.float32) * 0.1)
    g = jnp.array(rng.standard_normal((cfg.h, cfg.e)).astype(np.float32) * 0.1)
    h, logits = pre(x, w, w, w, w, g)
    assert h.shape == x.shape
    assert logits.shape == (cfg.tokens, cfg.e)
    assert np.isfinite(np.asarray(logits)).all()


def test_capacity_is_tile_aligned():
    for cfg in [CFG, model.MoEConfig(), model.MoEConfig(e=40, k=1, batch=8, seq=64)]:
        assert cfg.capacity % 8 == 0
        assert cfg.capacity * cfg.e >= cfg.tokens * cfg.k
